"""Cooperative peer-memory tier: equivalence, failure, and ownership suites.

The contract under test (see ``src/repro/storage/peer.py``): a shard's
:class:`~repro.storage.tiers.TierStack` extended with a
:class:`~repro.storage.peer.PeerTier` — HBM → host DRAM → peer DRAM →
backing store — returns *byte-identical* results to the flat-cache oracle
under ANY warm/ownership schedule, with warm cross-shard waves served from
the cluster's DRAM (zero backing-store reads).  Failure modes fall through
to the store (a dead peer costs I/O, never correctness or a wedged wave);
an append racing an in-flight remote read aborts it through the epoch
guard, exactly like :class:`~repro.storage.prefetch.TierPrefetcher`
speculation; and :class:`~repro.storage.rebalance.OwnershipRebalancer`
migrates block ownership toward observed heat without re-reading a byte.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.engine import NeedleTailEngine
from repro.core.multi_query import BatchQuery
from repro.data.block_store import Table, build_block_store
from repro.storage import (
    HeatTracker, OwnershipRebalancer, PeerTier, PeerUnavailable,
    make_peer_group,
)

pytestmark = pytest.mark.serving

RPB = 64
NB = RPB * (4 * 4 + 2 * 4 + 1)  # slab bytes of the 4-dim/2-measure tables


def _make_table(seed: int, n: int = 6_000) -> Table:
    rng = np.random.default_rng(seed)
    return Table(
        dims=rng.integers(0, 3, (n, 4)).astype(np.int32),
        measures=rng.normal(size=(n, 2)).astype(np.float32),
        cards=np.asarray([3, 3, 3, 3]),
    )


_STORES: dict = {}


def _store(seed: int):
    if seed not in _STORES:
        _STORES[seed] = build_block_store(_make_table(seed), RPB)
    return _STORES[seed]


QUERY_POOL = [
    ([(0, 1)], 40, "and"),
    ([(0, 1), (1, 1)], 120, "and"),
    ([(1, 1), (2, 1)], 60, "or"),
    ([(2, 0)], 25, "and"),
    ([(0, 1), (2, 1), (3, 1)], 200, "and"),
]


def _queries(spec=QUERY_POOL) -> list[BatchQuery]:
    return [BatchQuery(p, k, op) for (p, k, op) in spec]


def _assert_batch_equal(a, b):
    assert len(a.results) == len(b.results)
    for ra, rb in zip(a.results, b.results):
        np.testing.assert_array_equal(ra.record_block, rb.record_block)
        np.testing.assert_array_equal(ra.record_row, rb.record_row)
        np.testing.assert_array_equal(ra.measures, rb.measures)
        np.testing.assert_array_equal(ra.blocks_fetched, rb.blocks_fetched)


def _union_blocks(store, queries) -> list[int]:
    """The flat-oracle working set of `queries` (and the oracle batch)."""
    ref = NeedleTailEngine(store).any_k_batch(queries)
    return sorted({int(b) for r in ref.results for b in r.blocks_fetched}), ref


# ---------------------------------------------------------------------------
# Equivalence: warm peers serve the whole wave, byte-identical, 0 store reads.
# ---------------------------------------------------------------------------
def test_warm_peer_wave_is_byte_identical_and_store_free():
    store = _store(0)
    queries = _queries()
    union, ref = _union_blocks(store, queries)
    group = make_peer_group(store, n_shards=3)
    eng = NeedleTailEngine(store, tiers=group.stacks[0])

    # spread the working set over the OTHER shards: nothing local, all remote
    half = len(union) // 2
    group.warm(store, {1: union[:half], 2: union[half:]})

    stack = group.stacks[0]
    sf0 = stack.stats.store_blocks_fetched
    batch = eng.any_k_batch(queries)
    _assert_batch_equal(batch, ref)
    # every block came over the ici hop, none from the backing store
    assert stack.stats.store_blocks_fetched == sf0
    assert group.stats.remote_fetches > 0
    counters = stack.tier_counters()
    assert counters["peer.hits"] > 0
    assert counters["peer.remote_fetches"] == group.stats.remote_fetches


def test_peer_tier_is_skipped_by_placement():
    """Fresh store reads never land in the capacity-0 view tier, and a
    cold run (no peer holds anything) is a plain miss-to-store run."""
    store = _store(1)
    queries = _queries()
    union, ref = _union_blocks(store, queries)
    group = make_peer_group(store, n_shards=2, dram_bytes=3 * NB)
    eng = NeedleTailEngine(store, tiers=group.stacks[0])
    batch = eng.any_k_batch(queries)
    _assert_batch_equal(batch, ref)
    stack = group.stacks[0]
    peer = stack.peer_tier
    assert isinstance(peer, PeerTier)
    assert len(peer) == 0 and peer.stats.admissions == 0
    assert group.stats.remote_fetches == 0  # nothing was ever remote
    # eviction pressure demoted through dram; nothing tried to enter peer
    assert peer.stats.demotions_in == 0


@settings(max_examples=8, deadline=None)
@given(
    st.integers(0, 3),
    st.integers(1, 9),
    st.lists(st.integers(0, 10_000), max_size=6),
)
def test_equivalence_under_any_ownership_schedule(seed, split_tenths, migrations):
    """Byte-identity holds under ANY warm spread and ANY (adversarial)
    mid-run ownership-migration schedule."""
    store = _store(seed)
    queries = _queries()
    union, ref = _union_blocks(store, queries)
    group = make_peer_group(store, n_shards=3)
    eng = NeedleTailEngine(store, tiers=group.stacks[0])
    cut = len(union) * split_tenths // 10
    group.warm(store, {1: union[:cut], 2: union[cut:]})

    _assert_batch_equal(eng.any_k_batch(queries), ref)
    for m in migrations:  # adversarial migration between waves
        b = union[m % len(union)]
        group.migrate(b, (group.owner_of(b) + 1) % group.n_shards)
    _assert_batch_equal(eng.any_k_batch(queries), ref)


# ---------------------------------------------------------------------------
# Failure modes: a dead peer is a miss, never a wedged wave.
# ---------------------------------------------------------------------------
def test_raising_peer_falls_through_to_store():
    store = _store(2)
    queries = _queries()
    union, ref = _union_blocks(store, queries)
    group = make_peer_group(store, n_shards=3)
    eng = NeedleTailEngine(store, tiers=group.stacks[0])
    group.warm(store, {1: union})
    group.fail_shard(1, mode="raise")

    stack = group.stacks[0]
    sf0 = stack.stats.store_blocks_fetched
    batch = eng.any_k_batch(queries)  # must not raise or wedge
    _assert_batch_equal(batch, ref)
    assert stack.peer_tier.failures > 0  # fetches really were refused...
    assert group.stats.failed_fetches > 0
    assert stack.stats.store_blocks_fetched > sf0  # ...and the store served
    # the raise is still reachable directly — the TIER swallows it, not the group
    with pytest.raises(PeerUnavailable):
        group.fetch_block(union[0], requester=0)


def test_missing_peer_is_a_clean_miss():
    store = _store(3)
    queries = _queries()
    union, ref = _union_blocks(store, queries)
    group = make_peer_group(store, n_shards=3)
    eng = NeedleTailEngine(store, tiers=group.stacks[0])
    group.warm(store, {1: union})
    group.fail_shard(1, mode="miss")  # silently vanishes from the directory

    stack = group.stacks[0]
    sf0 = stack.stats.store_blocks_fetched
    _assert_batch_equal(eng.any_k_batch(queries), ref)
    assert stack.peer_tier.failures == 0  # no exception path taken
    assert group.stats.remote_fetches == 0
    assert stack.stats.store_blocks_fetched > sf0
    group.heal_shard(1)  # back up: remote serving resumes
    stack.clear()  # drop the local copies the miss wave admitted
    eng.any_k_batch(queries)
    assert group.stats.remote_fetches > 0


# ---------------------------------------------------------------------------
# Append racing a peer fetch: the epoch guard aborts the in-flight read.
# ---------------------------------------------------------------------------
def _fresh_append_fixture():
    """Fresh (non-memoized) store + group: the append mutates the store."""
    store = build_block_store(_make_table(7), RPB)
    group = make_peer_group(store, n_shards=2)
    eng = NeedleTailEngine(store, tiers=group.stacks[0])
    extra = _make_table(99, n=40)
    return store, group, eng, extra


def test_append_racing_peer_fetch_aborts_in_flight_read():
    store, group, eng, extra = _fresh_append_fixture()
    tail = store.num_blocks - 1  # the block the append will dirty
    group.warm(store, {1: [tail]})

    fired = []

    def hook(b):  # fires between the epoch snapshot and the slab copy
        if not fired:
            fired.append(b)
            eng.append(extra)

    group.mid_fetch_hook = hook
    out = group.fetch_block(tail, requester=0)
    assert fired, "hook never fired: fetch did not reach the race window"
    assert out is None  # the stale copy was NOT served
    assert group.stats.stale_aborts == 1
    # the append's invalidation listener also dropped the peer resident
    assert group.locate(tail) is None


def test_append_invalidates_peer_residents_like_local_tiers():
    store, group, eng, extra = _fresh_append_fixture()
    queries = _queries(QUERY_POOL[:2])
    union, _ = _union_blocks(store, queries)
    tail = store.num_blocks - 1
    group.warm(store, {1: sorted(set(union) | {tail})})

    grown = eng.append(extra)
    assert group.locate(tail) is None  # dirtied tail evicted on shard 1
    survivors = [b for b in union if b != tail]
    assert all(group.locate(b) == 1 for b in survivors)  # surgical, not flush
    # post-append waves run against the grown store, byte-identical
    ref = NeedleTailEngine(grown).any_k_batch(queries)
    _assert_batch_equal(eng.any_k_batch(queries), ref)


# ---------------------------------------------------------------------------
# Ownership migration: heat moves blocks toward the shard that touches them.
# ---------------------------------------------------------------------------
def test_ownership_migrates_toward_hot_shard():
    store = _store(4)
    queries = _queries()
    union, ref = _union_blocks(store, queries)
    group = make_peer_group(store, n_shards=3)
    eng = NeedleTailEngine(store, tiers=group.stacks[0])
    half = len(union) // 2
    group.warm(store, {1: union[:half], 2: union[half:]})

    # shard 0 hammers the working set: two waves of heat
    _assert_batch_equal(eng.any_k_batch(queries), ref)
    _assert_batch_equal(eng.any_k_batch(queries), ref)

    reb = OwnershipRebalancer(group, hysteresis=1.2, min_heat=0.5)
    moved = reb.rebalance()
    assert moved > 0 and reb.moves_applied == moved
    assert group.stats.migrations > 0  # resident slabs moved, not re-read
    # ownership followed the heat: every union block now owned by shard 0
    assert all(group.owner_of(b) == 0 for b in union)

    # post-migration wave: local DRAM serves, the ici hop goes quiet
    stack = group.stacks[0]
    sf0 = stack.stats.store_blocks_fetched
    rf0 = group.stats.remote_fetches
    _assert_batch_equal(eng.any_k_batch(queries), ref)
    assert stack.stats.store_blocks_fetched == sf0  # bytes moved, not re-read
    assert group.stats.remote_fetches == rf0  # no cross-shard traffic left


def test_rebalancer_hysteresis_and_cadence():
    store = _store(5)
    queries = _queries(QUERY_POOL[:2])
    union, ref = _union_blocks(store, queries)
    group = make_peer_group(store, n_shards=2)
    eng = NeedleTailEngine(store, tiers=group.stacks[0])
    group.warm(store, {1: union})
    ids = np.asarray(union, dtype=np.int64)
    group.stacks[1].get_many(store, ids)  # the owner touches its blocks too

    # an absurd hysteresis gate freezes ownership no matter the heat
    frozen = OwnershipRebalancer(group, hysteresis=1e9, min_heat=0.5)
    _assert_batch_equal(eng.any_k_batch(queries), ref)
    assert frozen.rebalance() == 0
    assert all(group.owner_of(b) == 1 for b in union)

    # tick() honors the cadence: only every `every`-th call rebalances
    for _ in range(4):  # shard 0's heat now dwarfs the owner's single touch
        _assert_batch_equal(eng.any_k_batch(queries), ref)
    reb = OwnershipRebalancer(group, hysteresis=1.2, min_heat=0.5, every=3)
    assert reb.tick() == 0 and reb.tick() == 0
    assert reb.tick() > 0  # third tick fires and migrates toward shard 0


def test_heat_tracker_decay_and_eviction_reset():
    store = _store(6)
    group = make_peer_group(store, n_shards=2)
    tracker = HeatTracker(group, decay=0.5)
    stack = group.stacks[0]
    stack.get_many(store, np.asarray([0, 1], dtype=np.int64))
    tracker.sample()
    h0 = tracker.heat[0][0]
    assert h0 > 0
    tracker.sample()  # no new touches: heat decays toward zero
    assert tracker.heat[0][0] == pytest.approx(h0 * 0.5)
    # a cleared ledger (eviction reset) clamps the delta, never negative
    stack.clear()
    tracker.sample()
    assert all(h >= 0 for h in tracker.heat[0].values())


def test_heat_tracker_invalidation_resets_heat_and_baseline():
    """Regression: an append/compaction rewriting a block id must not let
    the OLD content's accesses count toward whatever is re-admitted under
    the same id — stale heat keeps the id artificially hot, and a stale
    last-sample baseline double-counts through the eviction-clamp path."""
    store = _store(7)
    group = make_peer_group(store, n_shards=2)
    tracker = HeatTracker(group, decay=0.5)
    stack = group.stacks[0]
    stack.get_many(store, np.asarray([0, 0, 0, 1], dtype=np.int64))
    tracker.sample()
    assert tracker.heat[0][0] == pytest.approx(3.0)
    assert tracker._last[0][0] == 3
    # the append path notifies the dirtied id: every registered listener
    # (the shard stacks AND the tracker) forgets block 0; block 1 survives
    store.notify_invalidated(np.asarray([0], dtype=np.int64))
    assert 0 not in tracker.heat[0] and 0 not in tracker._last[0]
    assert tracker.heat[0][1] > 0
    # the re-admitted content starts cold: one post-rewrite access must fold
    # in as exactly 1 heat, not old_heat * decay + 1 (the double count)
    stack.get_many(store, np.asarray([0], dtype=np.int64))
    tracker.sample()
    assert tracker.heat[0][0] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Mesh routing: remote reads answered through DistributedAnyK.fetch_remote.
# ---------------------------------------------------------------------------
def test_mesh_routes_peer_fetches_through_distributed_planner():
    jax = pytest.importorskip("jax")
    store = _store(0)
    queries = _queries(QUERY_POOL[:3])
    union, ref = _union_blocks(store, queries)
    group = make_peer_group(store, n_shards=3)
    eng = NeedleTailEngine(store, tiers=group.stacks[0])
    dist = eng.attach_mesh(jax.make_mesh((1,), ("data",)), peer_group=group)
    assert dist.peer_group is group  # attach_mesh wired route_through
    group.warm(store, {1: union})

    out = dist.fetch_remote(union[:3], requester=0)
    assert sorted(out) == sorted(int(b) for b in union[:3])
    rf0 = group.stats.remote_fetches
    _assert_batch_equal(eng.any_k_batch(queries), ref)
    assert group.stats.remote_fetches >= rf0  # served through the planner
