"""Continuous-batching serving loop: slot-level join/leave, byte-identity to
the solo oracle, mid-wave refill under queue pressure, prefetch invalidation
on append, and the cost-fed admission gate.

The loop under test is ``ServeEngine.exemplar_tick`` (one refill round per
tick, freed slots refilled from the admission queue between rounds) plus its
admission/prefetch plumbing: ``AdmissionController.claim`` (mid-wave pops and
per-request requeue rollback), ``repro.storage.prefetch.TierPrefetcher``
(memo-predicted tier warming with append invalidation), and the
``cheap_cost_s`` cost gate fed by ``make_missed_cost_probe``.
"""
import numpy as np
import pytest

from repro.core.engine import NeedleTailEngine
from repro.core.multi_query import BatchQuery
from repro.data.block_store import Table, build_block_store
from repro.data.synthetic import make_clustered_table
from repro.serving.admission import AdmissionController, AdmissionPolicy
from repro.serving.engine import ServeEngine, SlotScheduler

pytestmark = pytest.mark.serving


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


def _underdelivery_table():
    """30 decoy blocks where A0/A1 alternate rows (estimated AND density
    0.25, actual 0) and 10 tail blocks holding the real joint matches —
    the joint query under-delivers round 0 and must refill."""
    rng = np.random.default_rng(0)
    rpb = 100
    n = 40 * rpb
    a0 = np.zeros(n, np.int32)
    a1 = np.zeros(n, np.int32)
    for b in range(30):
        lo = b * rpb
        a0[lo : lo + rpb : 2] = 1
        a1[lo + 1 : lo + rpb : 2] = 1
    for b in range(30, 40):
        lo = b * rpb
        a0[lo : lo + 30] = 1
        a1[lo : lo + 30] = 1
    return Table(
        dims=np.stack([a0, a1], axis=1),
        measures=rng.normal(size=(n, 1)).astype(np.float32),
        cards=np.asarray([2, 2]),
    ), rpb


@pytest.fixture(scope="module")
def clustered_store():
    t = make_clustered_table(num_records=12_000, num_dims=4, density=0.15,
                             seed=5)
    return build_block_store(t, records_per_block=64)


def _serve(max_slots, clock=None, **kw):
    """Exemplar-only serving engine (no LM) around a fixed slot pool."""
    return ServeEngine(
        None, None, max_slots=max_slots,
        exemplar_policy=AdmissionPolicy(slo_s=10.0, max_wave=max_slots),
        clock=clock or FakeClock(), **kw,
    )


def _drive(serve, eng, reqs, max_ticks=64):
    """Tick until every request completes; returns ticks executed."""
    ticks = 0
    while not all(r.done for r in reqs):
        serve.exemplar_tick(eng, drain=True)
        ticks += 1
        assert ticks <= max_ticks, "continuous loop did not converge"
    return ticks


def _assert_solo_identical(store, reqs):
    """Every request's rows byte-identical to a fresh cache-less solo
    ``any_k`` — continuous scheduling moves I/O and time, never bytes."""
    ref = NeedleTailEngine(store, cache_bytes=0)
    for r in reqs:
        solo = ref.any_k(r.predicates, r.k, op=r.op, algo="auto")
        np.testing.assert_array_equal(r.result.record_block, solo.record_block)
        np.testing.assert_array_equal(r.result.record_row, solo.record_row)
        np.testing.assert_array_equal(r.result.measures, solo.measures)
        assert r.result.plan_rounds == solo.plan_rounds


# ------------------------------------------------- (a) oracle byte-identity


@pytest.mark.parametrize("device", (False, True))
def test_continuous_rows_byte_identical_to_solo_anyk(device):
    """Mixed wave with a multi-round under-deliverer, more requests than
    slots: every completion matches the solo oracle byte for byte, on both
    the host and the device plan path (which must also keep the ≤1
    device→host transfer per tick ledger)."""
    t, rpb = _underdelivery_table()
    store = build_block_store(t, records_per_block=rpb)
    eng = NeedleTailEngine(store)
    serve = _serve(2, exemplar_device=device)
    reqs = [
        serve.submit_exemplar_request([(0, 1), (1, 1)], 250),  # refills
        serve.submit_exemplar_request([(0, 1)], 100),
        serve.submit_exemplar_request([(1, 1)], 100),
        serve.submit_exemplar_request([(0, 1)], 40),
    ]
    while not all(r.done for r in reqs):
        serve.exemplar_tick(eng, drain=True)
        st = serve.last_wave_stats
        if st is not None:
            assert st["device_transfers"] <= 1
    _assert_solo_identical(store, reqs)
    assert reqs[0].result.plan_rounds > 1  # the adversarial one really refilled
    assert reqs[0].result.num_records >= 250


def test_continuous_matches_solo_on_clustered(clustered_store):
    eng = NeedleTailEngine(clustered_store)
    serve = _serve(3)
    reqs = [
        serve.submit_exemplar_request([(0, 1), (2, 1)], 300),
        serve.submit_exemplar_request([(0, 1)], 50),
        serve.submit_exemplar_request([(1, 1), (3, 1)], 200, op="or"),
        serve.submit_exemplar_request([(2, 1)], 64),
        serve.submit_exemplar_request([(3, 1)], 16),
    ]
    _drive(serve, eng, reqs)
    _assert_solo_identical(clustered_store, reqs)


# ------------------------------------------- (b) mid-wave refill of freed slots


def test_freed_slot_reoccupied_next_round_under_pressure():
    """With a straggler holding one slot, a slot freed at round r must be
    re-occupied at round r+1 while the queue is non-empty: the planned wave
    size stays at max_slots, and the controller books the pops as
    ``refill_waves`` (mid-wave claims, not policy launches)."""
    t, rpb = _underdelivery_table()
    eng = NeedleTailEngine(build_block_store(t, records_per_block=rpb))
    serve = _serve(2)
    adm = serve.exemplar_admission
    straggler = serve.submit_exemplar_request([(0, 1), (1, 1)], 250)
    shorts = [serve.submit_exemplar_request([(0, 1)], 60) for _ in range(3)]
    wave_sizes = []
    while not all(r.done for r in [straggler, *shorts]):
        backlog_before = adm.pending
        serve.exemplar_tick(eng, drain=True)
        wave_sizes.append(serve.last_wave_stats["wave_size"])
        if backlog_before > 0:
            # queue pressure: the freed slot was refilled before planning
            assert serve.last_wave_stats["wave_size"] == 2
    assert adm.stats.refill_waves >= 1  # pops happened mid-wave
    assert wave_sizes[0] == 2
    # the straggler outlived every short request, so slots turned over
    assert straggler.result.plan_rounds > 1
    _assert_solo_identical(eng.store, [straggler, *shorts])


def test_slot_scheduler_occupancy_ledger():
    sched = SlotScheduler(2)
    s0 = sched.join("a")
    sched.tick()  # one round with 1/2 busy
    s1 = sched.join("b")
    sched.tick()  # one round with 2/2 busy
    assert sched.leave(s0) == "a"
    assert sched.busy == 1 and sched.free_slots() == [s0]
    assert sched.joins == 2 and sched.leaves == 1 and sched.rounds == 2
    assert sched.occupancy == pytest.approx(3 / 4)
    assert sched.join("c") == s0  # freed slot is immediately reusable
    assert s1 in sched.busy_slots()


# -------------------------------------------- (c) prefetch append invalidation


def test_prefetched_blocks_invalidated_by_append_like_residents():
    """A store append dirties the partial tail block; the prefetcher's
    speculation ledger must drop it exactly like the tiers drop their
    resident copy — stale speculative blocks never count as warm."""
    from repro.storage import make_tier_stack
    from repro.storage.prefetch import TierPrefetcher

    rng = np.random.default_rng(3)
    rpb = 64
    n = 6 * rpb - 10  # partial tail block: the append dirties it
    t = Table(
        dims=np.stack([np.ones(n, np.int32),
                       rng.integers(0, 2, n).astype(np.int32)], axis=1),
        measures=rng.normal(size=(n, 1)).astype(np.float32),
        cards=np.asarray([2, 2]),
    )
    store = build_block_store(t, records_per_block=rpb)
    stack = make_tier_stack(None, None)
    eng = NeedleTailEngine(store, tiers=stack)
    # memoize the round-0 plan over every block (k spans the whole table),
    # then clear the tiers so the prefetcher has real warming to do
    eng.any_k_batch([BatchQuery([(0, 1)], n)], algo="auto")
    stack.clear()
    pf = TierPrefetcher(eng)
    pf.kick([BatchQuery([(0, 1)], n)])
    tail = store.num_blocks - 1
    assert tail in pf.prefetched and 0 in pf.prefetched
    assert int(stack.residency_tier(np.asarray([tail]))[0]) < len(stack.tiers)

    extra = Table(dims=np.ones((rpb, 2), np.int32),
                  measures=rng.normal(size=(rpb, 1)).astype(np.float32),
                  cards=t.cards)
    eng.append(extra)  # dirties the tail block, notifies every listener
    assert tail not in pf.prefetched  # speculation pruned like residency
    assert pf.stats.invalidated >= 1
    assert 0 in pf.prefetched  # untouched blocks stay warm
    assert int(stack.residency_tier(np.asarray([tail]))[0]) == len(stack.tiers)
    assert int(stack.residency_tier(np.asarray([0]))[0]) < len(stack.tiers)


def _prefetch_fixture(hbm_bytes=None, dram_bytes=None):
    """Engine + tier stack + a memoized whole-table plan, tiers cleared —
    the prefetcher has the full block union to warm."""
    from repro.storage import make_tier_stack

    rng = np.random.default_rng(5)
    rpb = 64
    n = 8 * rpb
    t = Table(
        dims=np.stack([np.ones(n, np.int32),
                       rng.integers(0, 2, n).astype(np.int32)], axis=1),
        measures=rng.normal(size=(n, 1)).astype(np.float32),
        cards=np.asarray([2, 2]),
    )
    store = build_block_store(t, records_per_block=rpb)
    stack = make_tier_stack(hbm_bytes, dram_bytes)
    eng = NeedleTailEngine(store, tiers=stack)
    req = [BatchQuery([(0, 1)], n)]
    eng.any_k_batch(req, algo="auto")
    stack.clear()
    return eng, stack, req


def test_prefetch_kick_truncates_after_sorting():
    """The per-kick cap keeps the ascending §4.1 *prefix* of the predicted
    union — the locality-dense end — and counts the drop (never silent)."""
    from repro.storage.prefetch import TierPrefetcher, predicted_wave_blocks

    eng, stack, req = _prefetch_fixture()
    union, _ = predicted_wave_blocks(eng, req, {})
    assert union.size > 3  # the cap below really bites
    pf = TierPrefetcher(eng, max_blocks=3)
    issued = pf.kick(req)
    assert issued == 3 and pf.stats.issued == 3
    assert pf.stats.truncated == int(union.size) - 3
    # kept the 3 LOWEST block ids: the sorted prefix, not arrival order
    assert pf.prefetched == set(sorted(int(b) for b in union)[:3])


def test_async_drain_credits_only_admitted_blocks():
    """`fetched` counts blocks the cache reports moved — an async read the
    budget rejects (or an append staled) is wasted bandwidth, not a fetch."""
    from repro.storage.prefetch import TierPrefetcher

    # budgets too small for even one slab: every admission is rejected
    eng, stack, req = _prefetch_fixture(hbm_bytes=8, dram_bytes=8)
    pf = TierPrefetcher(eng, async_fetch=True)
    issued = pf.kick(req)
    assert issued > 0
    moved = pf.drain(wait=True)
    assert moved == 0 and pf.stats.fetched == 0  # nothing actually landed

    # control: a roomy stack credits exactly what the drain admitted
    eng2, stack2, req2 = _prefetch_fixture()
    pf2 = TierPrefetcher(eng2, async_fetch=True)
    issued2 = pf2.kick(req2)
    moved2 = pf2.drain(wait=True)
    assert moved2 == issued2 and pf2.stats.fetched == issued2


# ------------------------------------------------- (d) cost-fed admission gate


def test_cost_fed_policy_launches_cheap_wave_holds_cold_one():
    """Two single-request waves under a lax deadline: the memoized,
    tier-resident one prices at ~0 and launches immediately through the
    ``cheap_cost_s`` gate; the cold (unmemoized) one holds until its SLO
    deadline forces it out."""
    from repro.storage import make_tier_stack

    t = make_clustered_table(num_records=8_000, num_dims=4, density=0.2,
                             seed=11)
    store = build_block_store(t, records_per_block=64)
    stack = make_tier_stack(None, None)
    eng = NeedleTailEngine(store, tiers=stack)
    clk = FakeClock()
    serve = ServeEngine(
        None, None, max_slots=4,
        exemplar_policy=AdmissionPolicy(slo_s=5.0, max_wave=4,
                                        cheap_cost_s=1e-4),
        clock=clk,
    )
    adm = serve.exemplar_admission
    # warm the memo AND the tiers for the hot template
    eng.any_k_batch([BatchQuery([(0, 1)], 32)], algo="auto")

    hot = serve.submit_exemplar_request([(0, 1)], 32)
    serve.exemplar_tick(eng)  # idle claim, policy-gated: cheap fires
    assert hot.done and adm.stats.cheap_waves == 1
    assert adm.stats.deadline_waves == 0

    cold = serve.submit_exemplar_request([(1, 1), (3, 1)], 500)  # no memo
    serve.exemplar_tick(eng)
    assert not cold.done and adm.pending == 1  # unpriceable: held back
    clk.advance(5.0)  # ... until the SLO deadline comes due
    while not cold.done:
        serve.exemplar_tick(eng)
    assert adm.stats.deadline_waves >= 1
    _assert_solo_identical(store, [hot, cold])


# ----------------------------------- satellite: admission stats requeue rollback


def test_partial_requeue_rolls_back_per_request_stats():
    """Requeuing part of a popped wave must not double-count the requeued
    requests in served/wait stats while the successfully-served remainder
    keeps its accounting; the wave itself unwinds only when every request
    of the pop is returned."""
    clk = FakeClock()
    adm = AdmissionController(AdmissionPolicy(slo_s=0.1, max_wave=3),
                              clock=clk)
    for name in ("a", "b", "c"):
        adm.submit(name)
    clk.advance(0.2)
    wave = adm.poll()
    assert wave == ["a", "b", "c"]
    assert adm.stats.served == 3 and adm.stats.waves == 1
    w3 = adm.stats.total_wait_s

    adm.requeue_front(wave[1:])  # "a" succeeded, "b"/"c" go back
    assert adm.stats.served == 1
    assert adm.stats.waves == 1  # the wave still launched
    assert adm.stats.total_wait_s == pytest.approx(w3 / 3)
    assert adm.pending == 2

    clk.advance(0.2)
    wave2 = adm.poll()
    assert wave2 == ["b", "c"]  # FIFO order survives the rollback
    assert adm.stats.served == 3
    # re-served requests count ONE wait each (from requeue time), so the
    # failed attempt is neither double-counted nor silently dropped
    assert adm.stats.total_wait_s == pytest.approx(w3 / 3 + 2 * 0.2)
    assert adm.stats.mean_wait_s == pytest.approx(adm.stats.total_wait_s / 3)


def test_full_requeue_unwinds_the_wave():
    clk = FakeClock()
    adm = AdmissionController(AdmissionPolicy(slo_s=0.1, max_wave=2),
                              clock=clk)
    adm.submit("a"), adm.submit("b")
    wave = adm.poll()
    assert adm.stats.waves == 1 and adm.stats.full_waves == 1
    adm.requeue_front(wave)
    assert adm.stats.served == 0 and adm.stats.waves == 0
    assert adm.stats.full_waves == 0 and adm.stats.total_wait_s == 0.0
    assert adm.poll() == ["a", "b"]


# --------------------------------------- satellite: classic-path slot_occupancy


def test_wave_drain_surfaces_slot_occupancy(clustered_store):
    """The classic drain path reports per-round busy-slot occupancy — the
    number the continuous loop exists to push toward 1.0 (a satisfied query
    parks its slot for the wave's remaining rounds)."""
    eng = NeedleTailEngine(clustered_store)
    serve = _serve(4)
    for k in (300, 50, 200, 16):
        serve.submit_exemplar_request([(0, 1)], k)
    done = serve.drain_exemplar_requests(eng)
    assert len(done) == 4
    occ = serve.last_wave_stats["slot_occupancy"]
    assert 0.0 < occ <= 1.0
    assert serve.last_wave_stats["modeled_store_io_s"] >= 0.0


# --------------------------------------------------- continuous LM slot joins


def test_lm_continuous_join_byte_exact_vs_solo():
    """A prompt joining the live LM wave mid-decode (left-padded to the
    shared position counter, cache rows grafted) must emit exactly the
    tokens a solo wave run would — batch rows are independent."""
    import jax

    from repro.configs import get_config, reduced
    from repro.models import init_params

    cfg = reduced(get_config("qwen1.5-4b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    pa = np.arange(6, dtype=np.int32) + 3
    pb = np.arange(6, dtype=np.int32) + 11  # same length: joins at pos

    solo = ServeEngine(cfg, params, max_slots=2, max_seq=32)
    solo.submit(pb, max_new_tokens=5)
    want = solo.run_until_drained()[0].out_tokens

    eng = ServeEngine(cfg, params, max_slots=2, max_seq=32)
    ra = eng.submit(pa, max_new_tokens=8)
    eng.lm_tick()  # prefill tick seats A; pos == len(pa)
    rb = eng.submit(pb, max_new_tokens=5)
    for _ in range(16):
        if ra.done and rb.done:
            break
        eng.lm_tick()
    assert rb.done and rb.out_tokens == want
