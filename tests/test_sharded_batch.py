"""Sharded batched planning: one shard_map collective plans a whole wave.

Byte-identity of `DistributedAnyK.any_k_batch` against the host-mirror batch
path (and therefore against sequential `any_k`) on clustered / uniform /
skewed layouts with AND and OR templates, plus the edge cases: a Q=1 wave, a
wave whose size does not divide the shard count, queries hitting disjoint
shards, and a cache-warm sharded replan (0 store reads).  Multi-device cases
run in a subprocess so the main pytest process keeps exactly 1 CPU device
(same harness as tests/test_distributed.py).
"""
import json
import subprocess
import sys
import textwrap

PREAMBLE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.core.engine import NeedleTailEngine
from repro.core.multi_query import BatchQuery
from repro.data.block_store import Table, build_block_store

def _same(h, s):
    return (np.array_equal(h.record_block, s.record_block)
            and np.array_equal(h.record_row, s.record_row)
            and np.array_equal(h.measures, s.measures)
            and np.array_equal(np.sort(h.blocks_fetched), np.sort(s.blocks_fetched))
            and h.plan_rounds == s.plan_rounds and h.algo == s.algo)

def _compare(store, queries, mesh, algos=("threshold", "two_prong", "auto")):
    out = {}
    for algo in algos:
        host = NeedleTailEngine(store).any_k_batch(queries, algo=algo)
        eng = NeedleTailEngine(store)
        eng.attach_mesh(mesh)
        sh = eng.any_k_batch(queries, algo=algo)
        out[algo] = all(_same(h, s) for h, s in zip(host.results, sh.results))
        # the sequential oracle: the host batch path is itself locked to
        # any_k by tests/test_multi_query.py, but re-check one query here
        q0 = queries[0]
        seq = NeedleTailEngine(store).any_k(q0.predicates, q0.k, op=q0.op, algo=algo)
        out[algo] = out[algo] and _same(seq, sh.results[0])
    return out
"""


def _run(body: str) -> dict:
    code = PREAMBLE + textwrap.dedent(body)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo", timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_wave_byte_identical_across_layouts():
    """Acceptance: clustered / uniform / skewed, AND and OR templates, all
    planners — sharded any_k_batch is byte-identical to the host path."""
    res = _run("""
    from repro.data.synthetic import make_clustered_table
    mesh = jax.make_mesh((8,), ("data",))
    out = {}

    t = make_clustered_table(num_records=16_000, num_dims=4, density=0.15, seed=2)
    store = build_block_store(t, records_per_block=100)  # lam divisible by 8
    out["clustered"] = _compare(store, [
        BatchQuery([(0, 1), (2, 1)], 300),
        BatchQuery([(0, 1)], 50),
        BatchQuery([(1, 1), (3, 1)], 200, op="or"),
        BatchQuery([(2, 0)], 10),
    ], mesh)

    rng = np.random.default_rng(7)  # uniform: lam=235, NOT divisible by 8
    t = Table(dims=rng.integers(0, 3, (15_000, 3)).astype(np.int32),
              measures=rng.normal(size=(15_000, 2)).astype(np.float32),
              cards=np.asarray([3, 3, 3]))
    out["uniform"] = _compare(build_block_store(t, records_per_block=64), [
        BatchQuery([(0, 0)], 40),
        BatchQuery([(1, 0), (2, 2)], 80),
        BatchQuery([(0, 0), (1, 1)], 500, op="or"),
    ], mesh)

    rng = np.random.default_rng(3)  # skewed: density piled at one end
    n = 8_000
    a0 = np.zeros(n, np.int32); a0[:500] = 1
    a1 = rng.integers(0, 2, n).astype(np.int32)
    t = Table(dims=np.stack([a0, a1], axis=1),
              measures=rng.normal(size=(n, 1)).astype(np.float32),
              cards=np.asarray([2, 2]))
    out["skewed"] = _compare(build_block_store(t, records_per_block=50), [
        BatchQuery([(0, 1)], 400),
        BatchQuery([(0, 1), (1, 1)], 200),
        BatchQuery([(0, 1), (1, 0)], 100, op="or"),
    ], mesh)
    print(json.dumps(out))
    """)
    for layout, algos in res.items():
        assert all(algos.values()), (layout, algos)


def test_sharded_wave_edge_cases():
    """Q=1 waves, wave sizes that do not divide the shard count, and a wave
    whose queries hit disjoint shards (plan union spans both extremes)."""
    res = _run("""
    mesh = jax.make_mesh((8,), ("data",))
    out = {}

    # disjoint-shard layout: 64 blocks over 8 shards; attr0 matches only
    # shard 0's block range, attr1 only shard 7's
    rpb = 100
    n = 64 * rpb
    a0 = np.zeros(n, np.int32); a0[: 8 * rpb] = 1          # blocks 0..7
    a1 = np.zeros(n, np.int32); a1[56 * rpb:] = 1          # blocks 56..63
    a2 = (np.arange(n) // rpb % 2).astype(np.int32)        # everywhere
    rng = np.random.default_rng(0)
    t = Table(dims=np.stack([a0, a1, a2], axis=1),
              measures=rng.normal(size=(n, 1)).astype(np.float32),
              cards=np.asarray([2, 2, 2]))
    store = build_block_store(t, records_per_block=rpb)

    out["q1"] = _compare(store, [BatchQuery([(0, 1)], 120)], mesh)
    out["q3_not_divisible"] = _compare(store, [
        BatchQuery([(0, 1)], 150),
        BatchQuery([(1, 1)], 150),
        BatchQuery([(2, 1)], 90),
    ], mesh)
    out["q5_not_divisible"] = _compare(store, [
        BatchQuery([(0, 1)], 60), BatchQuery([(1, 1)], 60),
        BatchQuery([(0, 1), (2, 1)], 90), BatchQuery([(1, 1), (2, 0)], 90),
        BatchQuery([(0, 1), (1, 1)], 10),  # matches nowhere: plans run dry
    ], mesh)

    # the disjoint pair really planned blocks on opposite shards
    eng = NeedleTailEngine(store)
    eng.attach_mesh(mesh)
    b = eng.any_k_batch(
        [BatchQuery([(0, 1)], 150), BatchQuery([(1, 1)], 150)], algo="threshold"
    )
    s0 = set(b.results[0].blocks_fetched.tolist())
    s1 = set(b.results[1].blocks_fetched.tolist())
    out["disjoint"] = bool(
        s0 and s1 and not (s0 & s1)
        and max(s0) < 8 and min(s1) >= 56
    )
    print(json.dumps(out))
    """)
    for case, ok in res.items():
        if isinstance(ok, dict):
            assert all(ok.values()), (case, ok)
        else:
            assert ok, case


def test_sharded_warm_replan_reads_zero_store_blocks():
    """Cache-warm sharded replan: the repeat wave is served entirely from the
    engine-lifetime LRU (0 physical store reads, mirroring the host smoke
    guard) and reuses the sharded plan memo."""
    res = _run("""
    from repro.data.synthetic import make_clustered_table
    mesh = jax.make_mesh((8,), ("data",))
    t = make_clustered_table(num_records=16_000, num_dims=4, density=0.15, seed=2)
    store = build_block_store(t, records_per_block=100)
    queries = [
        BatchQuery([(0, 1), (2, 1)], 300),
        BatchQuery([(0, 1)], 50),
        BatchQuery([(1, 1), (3, 1)], 200, op="or"),
    ]
    eng = NeedleTailEngine(store)
    eng.attach_mesh(mesh)
    cold = eng.any_k_batch(queries, algo="auto")
    warm = eng.any_k_batch(queries, algo="auto")
    host = NeedleTailEngine(store, cache_bytes=0)
    seq_same = all(
        _same(host.any_k(q.predicates, q.k, op=q.op, algo="auto"), w)
        for q, w in zip(queries, warm.results)
    )
    print(json.dumps({
        "cold_reads": int(cold.store_blocks_fetched),
        "cold_unique": int(cold.unique_blocks_fetched.size),
        "warm_reads": int(warm.store_blocks_fetched),
        "warm_hits": int(warm.cache_hits),
        "memo_hits": int(eng.plan_cache.stats.sharded_threshold_hits
                         + eng.plan_cache.stats.two_prong_hits),
        "seq_same": bool(seq_same),
    }))
    """)
    assert res["cold_reads"] == res["cold_unique"] > 0, res
    assert res["warm_reads"] == 0, res
    assert res["warm_hits"] > 0 and res["memo_hits"] > 0, res
    assert res["seq_same"], res


def test_group_aligned_windows_do_not_poison_shared_memo():
    """two_prong_group > 1 windows are approximate (group-aligned); they must
    bypass the exact (row, need) window memo the host path shares, and a
    replace_store must refresh the attached planner's records_per_block."""
    res = _run("""
    from repro.data.synthetic import make_clustered_table
    mesh = jax.make_mesh((8,), ("data",))
    t = make_clustered_table(num_records=16_000, num_dims=4, density=0.15, seed=2)
    store = build_block_store(t, records_per_block=100)
    queries = [BatchQuery([(0, 1), (2, 1)], 300),
               BatchQuery([(1, 1), (3, 1)], 200, op="or")]
    eng = NeedleTailEngine(store)
    eng.attach_mesh(mesh, two_prong_group=4)
    eng.any_k_batch(queries, algo="two_prong")  # sharded: approximate windows
    host = eng.any_k_batch(queries, algo="two_prong", sharded=False)
    ref = NeedleTailEngine(store, cache_bytes=0)
    unpoisoned = all(
        _same(ref.any_k(q.predicates, q.k, op=q.op, algo="two_prong"), r)
        for q, r in zip(queries, host.results)
    )

    t2 = make_clustered_table(num_records=12_800, num_dims=4, density=0.15, seed=5)
    store64 = build_block_store(t2, records_per_block=64)
    eng2 = NeedleTailEngine(store)
    eng2.attach_mesh(mesh)
    eng2.replace_store(store64)
    sh = eng2.any_k_batch(queries, algo="auto")
    ref64 = NeedleTailEngine(store64, cache_bytes=0)
    rpb_ok = eng2.distributed.rpb == 64 and all(
        _same(ref64.any_k(q.predicates, q.k, op=q.op, algo="auto"), r)
        for q, r in zip(queries, sh.results)
    )
    print(json.dumps({"unpoisoned": bool(unpoisoned), "rpb_ok": bool(rpb_ok)}))
    """)
    assert res["unpoisoned"] and res["rpb_ok"], res


def test_serving_exemplar_wave_routes_through_sharded_path():
    """ServeEngine with a configured mesh attaches it to the any-k engine on
    the first wave; results stay byte-identical to the host-planned wave."""
    res = _run("""
    import collections, itertools
    from repro.data.synthetic import make_clustered_table
    from repro.serving.engine import ServeEngine
    mesh = jax.make_mesh((8,), ("data",))
    t = make_clustered_table(num_records=16_000, num_dims=4, density=0.15, seed=2)
    store = build_block_store(t, records_per_block=100)
    eng = NeedleTailEngine(store)
    serve = ServeEngine.__new__(ServeEngine)  # no LM needed for exemplar path
    serve.max_slots = 4
    serve.exemplar_queue = collections.deque()
    serve._rid = itertools.count()
    serve.exemplar_mesh = mesh
    reqs = [serve.submit_exemplar_request([(0, 1), (2, 1)], 50) for _ in range(6)]
    reqs.append(serve.submit_exemplar_request([(1, 1)], 30))
    done = serve.drain_exemplar_requests(eng)
    ref_eng = NeedleTailEngine(store)
    ok = all(
        _same(ref_eng.any_k(r.predicates, r.k, op=r.op, algo="auto"), r.result)
        for r in done
    )
    print(json.dumps({
        "done": len(done),
        "attached": eng.distributed is not None,
        "sharded_planner_used": int(
            eng.plan_cache.stats.sharded_threshold_hits
            + eng.plan_cache.stats.sharded_threshold_misses) > 0,
        "identical": bool(ok),
    }))
    """)
    assert res["done"] == 7 and res["attached"], res
    assert res["sharded_planner_used"], res
    assert res["identical"], res
