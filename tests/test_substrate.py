"""Substrate: checkpointing (atomicity/keep-k/resume), data pipeline
(filter correctness, determinism, epoch reset, hedged fetch), optimizer,
cost model, serving engine."""
import dataclasses
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step
from repro.core.cost_model import fit_cost_curve, make_cost_model, profile_and_fit
from repro.data.pipeline import (
    FilteredBatchStream, PipelineState, hedged_fetch, make_token_corpus, parse_filter,
)
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, warmup_cosine
from repro.optim.compress import compress_grads, compress_init, decompress_grads

# ---------------------------------------------------------------- checkpoint


def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.int32)}}
    for step in (1, 2, 3):
        mgr.save(step, state, extra={"tag": step})
    assert latest_step(tmp_path) == 3
    kept = sorted(p.name for p in tmp_path.iterdir() if p.name.startswith("step_"))
    assert kept == ["step_2", "step_3"]  # keep-k pruning
    abstract = jax.eval_shape(lambda: state)
    restored, step = mgr.restore(abstract)
    assert step == 3
    np.testing.assert_array_equal(restored["a"], state["a"])
    np.testing.assert_array_equal(restored["b"]["c"], state["b"]["c"])


def test_checkpoint_partial_save_is_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    state = {"x": jnp.zeros(3)}
    mgr.save(5, state)
    # simulate a crash mid-save: uncommitted dir
    bad = tmp_path / "step_9"
    bad.mkdir()
    (bad / "meta.json").write_text("{}")
    assert latest_step(tmp_path) == 5  # sentinel missing -> ignored
    CheckpointManager(tmp_path)  # re-init garbage-collects it
    assert not bad.exists()


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"x": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        mgr.restore({"x": jax.ShapeDtypeStruct((3, 3), jnp.float32)})


# ------------------------------------------------------------------ pipeline


def test_filtered_stream_only_matching_records():
    store, tokens = make_token_corpus(num_seqs=512, seq_len=32, seed=1)
    preds = parse_filter("domain=code")
    stream = FilteredBatchStream(store, tokens, preds, batch_size=8, seed=0)
    dims = np.asarray(store.dims).reshape(-1, store.dims.shape[-1])
    for _ in range(4):
        b = next(stream)
        assert b["tokens"].shape == (8, 31)
        assert np.all(dims[b["record_ids"], 0] == 1)  # domain == code


def test_filtered_stream_restart_exact():
    store, tokens = make_token_corpus(num_seqs=512, seq_len=32, seed=1)
    preds = parse_filter("quality=hi")
    s1 = FilteredBatchStream(store, tokens, preds, batch_size=8, seed=0)
    ids = [next(s1)["record_ids"] for _ in range(3)]
    snapshot = PipelineState(
        consumed=s1.state.consumed.copy(), round=s1.state.round,
        rng_counter=s1.state.rng_counter,
    )
    buffered = list(s1._buffer)
    after = [next(s1)["record_ids"] for _ in range(2)]
    # restart from snapshot (as the checkpoint would)
    s2 = FilteredBatchStream(store, tokens, preds, batch_size=8, seed=0, state=snapshot)
    s2._buffer = buffered
    after2 = [next(s2)["record_ids"] for _ in range(2)]
    for a, b in zip(after, after2):
        np.testing.assert_array_equal(a, b)


def test_filtered_stream_epoch_reset():
    store, tokens = make_token_corpus(num_seqs=128, seq_len=16, seed=2)
    preds = parse_filter("lang=zh")
    stream = FilteredBatchStream(store, tokens, preds, batch_size=4, seed=0)
    n_match = int((np.asarray(store.dims).reshape(-1, 4)[:, 2] == 1).sum())
    draws = 0
    for _ in range(max(n_match // 4 * 2, 8)):  # force >1 epoch
        next(stream)
        draws += 4
    assert stream.state.round >= 1  # exclusion set was reset at least once


def test_hedged_fetch_bounds_stragglers():
    store, _ = make_token_corpus(num_seqs=256, seq_len=16, seed=3)
    blocks = np.arange(8)
    rng = np.random.default_rng(0)

    def latency(ids, attempt):
        base = np.full(len(ids), 1.0)
        if attempt == 0:
            base[3] = 50.0  # one straggler
        return base + rng.random(len(ids)) * 0.1

    _, t = hedged_fetch(store, blocks, latency, hedge_quantile=0.8)
    assert t < 5.0  # straggler replaced by its hedge


# ----------------------------------------------------------------- optimizer


def test_adamw_descends_quadratic():
    p = {"w": jnp.asarray([5.0, -3.0])}
    st = adamw_init(p)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        p, st = adamw_update(p, g, st, lr=0.05, weight_decay=0.0)
    assert float(jnp.abs(p["w"]).max()) < 0.3


def test_clip_by_global_norm():
    g = {"a": jnp.full(4, 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_warmup_cosine_shape():
    assert float(warmup_cosine(0, 1e-3, 10, 100)) == 0.0
    assert float(warmup_cosine(10, 1e-3, 10, 100)) == pytest.approx(1e-3)
    assert float(warmup_cosine(100, 1e-3, 10, 100)) == pytest.approx(1e-4, rel=0.01)


def test_gradient_compression_error_feedback():
    """Accumulated dequantized grads converge to accumulated true grads."""
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(0, 1, 256).astype(np.float32))}
    st = compress_init(g_true)
    acc_q = np.zeros(256)
    steps = 50
    for _ in range(steps):
        q, st = compress_grads(g_true, st)
        acc_q += np.asarray(decompress_grads(q)["w"])
    rel = np.abs(acc_q / steps - np.asarray(g_true["w"])).max()
    assert rel < 0.01  # error feedback keeps long-run average unbiased


# ----------------------------------------------------------------- cost model


def test_fit_cost_curve_recovers_families():
    x = np.arange(1, 40, dtype=np.float64)
    name, fn, r2 = fit_cost_curve(x, 3.0 * x + 2.0)
    assert name == "linear" and r2 > 0.999
    name, fn, r2 = fit_cost_curve(x, 2.0 * np.log(x) + 1.0)
    assert name == "logarithmic" and r2 > 0.999


def test_profile_and_fit_and_io_time():
    cm = profile_and_fit(
        sample_times=lambda d: 1e-3 + d * 1e-4, max_dist=32, far_cost=7e-3,
        seq_cost=1e-3, first_block_cost=7e-3,
    )
    assert cm.io_time([5]) == pytest.approx(7e-3)
    seq = cm.io_time([1, 2, 3, 4])
    spread = cm.io_time([1, 100, 200, 300])
    assert spread > seq  # seeks cost more
    hdd = make_cost_model("hdd")
    assert hdd.rand_io(0, 1) < hdd.rand_io(0, 1000)


def test_io_time_deduplicates_block_ids():
    """A block id repeated across a wave's per-query plans is one physical
    fetch — io_time must not charge the duplicate an extra rand_io seek."""
    cm = make_cost_model("hdd")
    assert cm.io_time([5, 5, 5]) == cm.io_time([5])
    assert cm.io_time([1, 7, 1, 7, 3]) == cm.io_time([1, 3, 7])
    # transitively: the residency-aware stack price dedupes too
    from repro.storage import make_tier_stack

    stack = make_tier_stack(None, None)
    assert stack.effective_io_time([9, 9, 2]) == stack.effective_io_time([2, 9])


def test_io_time_dedup_survives_calibration():
    """Refitting the backing model from measured timings must not change
    the dedup/override semantics the §7.2 arbitration depends on."""
    from repro.storage import SyntheticTimingBackend, make_tier_stack

    stack = make_tier_stack(None, None, backing="ssd")
    stack.calibrate(SyntheticTimingBackend({"ssd": make_cost_model("hdd")}))
    assert stack.effective_io_time([9, 9, 2]) == stack.effective_io_time([2, 9])
    # cold sets now price at the fitted (hdd-like) backing...
    hdd = make_cost_model("hdd")
    got, want = stack.effective_io_time([2, 9]), hdd.io_time([2, 9])
    q = got / want
    assert max(q, 1.0 / q) < 1.5
    # ...and an explicit `backing=` override still wins over the fit
    assert stack.effective_io_time([2, 9], backing=hdd) == pytest.approx(want)


# ------------------------------------------------------------------- serving


def test_serve_engine_matches_manual_greedy():
    from repro.configs import get_config, reduced
    from repro.models import decode_step, init_params, prefill
    from repro.serving import ServeEngine

    cfg = reduced(get_config("qwen1.5-4b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.arange(5, dtype=np.int32) + 7
    eng = ServeEngine(cfg, params, max_slots=2, max_seq=32)
    eng.submit(prompt, max_new_tokens=6)
    done = eng.run_until_drained()
    got = done[0].out_tokens
    # manual greedy loop, batch=1... but the engine pads batch to max_slots;
    # rows are independent so results must match a batch-1 run
    last, cache = prefill(params, jnp.asarray(prompt)[None], cfg, max_seq=32)
    want = [int(jnp.argmax(last[0]))]
    pos = len(prompt)
    for _ in range(5):
        lg, cache = decode_step(params, cache, jnp.asarray([want[-1]], jnp.int32),
                                jnp.int32(pos), cfg)
        want.append(int(jnp.argmax(lg[0])))
        pos += 1
    assert got == want
