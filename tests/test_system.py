"""End-to-end system behaviour: train loop with NeedleTail-filtered data,
checkpoint/restart exactness, serve launcher, group-by quotas."""
import subprocess
import sys

import numpy as np
import pytest


def test_train_launcher_end_to_end(tmp_path):
    from repro.launch.train import main

    loss = main([
        "--arch", "mamba2-130m", "--reduced", "--steps", "8", "--batch", "4",
        "--seq", "48", "--filter", "domain=code", "--corpus-seqs", "512",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "4", "--log-every", "4",
    ])
    assert np.isfinite(loss)
    from repro.checkpoint import latest_step

    assert latest_step(tmp_path) == 8


def test_train_restart_is_exact(tmp_path):
    """Crash-restart: 4 steps + resume-to-8 must equal an uninterrupted 8."""
    from repro.launch.train import main

    args = ["--arch", "qwen1.5-4b", "--reduced", "--steps", "8", "--batch", "4",
            "--seq", "32", "--filter", "quality=hi", "--corpus-seqs", "256",
            "--ckpt-every", "4", "--log-every", "8"]
    loss_straight = main(args + ["--ckpt-dir", str(tmp_path / "a")])
    # interrupted run: stop at 4 (ckpt-every=4 commits step 4), then resume
    main(["--arch", "qwen1.5-4b", "--reduced", "--steps", "4", "--batch", "4",
          "--seq", "32", "--filter", "quality=hi", "--corpus-seqs", "256",
          "--ckpt-every", "4", "--log-every", "8", "--ckpt-dir", str(tmp_path / "b")])
    loss_resumed = main(args + ["--ckpt-dir", str(tmp_path / "b")])
    assert loss_resumed == pytest.approx(loss_straight, rel=1e-4)


def test_serve_launcher_end_to_end():
    from repro.launch.serve import main

    n = main(["--arch", "qwen1.5-4b", "--reduced", "--requests", "3",
              "--max-new", "4", "--slots", "2", "--max-seq", "48"])
    assert n == 3


def test_groupby_quota_batching():
    """Appendix A: k samples per group through the priority-reweighted engine."""
    from repro.core.engine import NeedleTailEngine
    from repro.core.groupby import groupby_any_k
    from repro.data.block_store import build_block_store
    from repro.data.synthetic import make_real_like_table

    t = make_real_like_table("taxi", num_records=20_000, seed=1)
    store = build_block_store(t, records_per_block=100)
    eng = NeedleTailEngine(store)
    res = groupby_any_k(eng, [(2, 3)], group_attr=0, k=15, psi=8)
    assert np.all(res.per_group_counts >= 15)
    dims = np.asarray(store.dims)
    for b, row, g in zip(res.record_block, res.record_row, res.record_group):
        assert dims[b, row, 0] == g and dims[b, row, 2] == 3


def test_dryrun_entry_importable_without_devices():
    """mesh.py import must not touch jax device state."""
    out = subprocess.run(
        [sys.executable, "-c",
         "import repro.launch.mesh as m; import jax; "
         "assert len(jax.devices()) == 1, jax.devices(); print('ok')"],
        capture_output=True, text=True, cwd="/root/repo",
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"},
        timeout=120,
    )
    assert out.returncode == 0 and "ok" in out.stdout, out.stderr[-2000:]
