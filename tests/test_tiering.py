"""Tiered block storage: equivalence, placement, and residency-aware suites.

The contract under test (see ``src/repro/storage/tiers.py``): a
:class:`~repro.storage.tiers.TierStack` dropped in as the engine's block
cache returns *byte-identical* results to the flat-cache oracle under ANY
tier budgets and ANY placement policy — eviction pressure (demotion
cascades), drops, append invalidation (every tier evicts the dirtied tail),
and the device pipeline under a tiny tier-0 budget included.  Placement
behavior itself (admission / promotion / demotion / victim selection by
modeled io_time saved per byte) is asserted through the per-tier counters,
and the residency-aware layers on top — effective-cost §7.2 arbitration and
the admission controller's early resident-wave launch — get targeted
scenario tests.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cost_model import make_cost_model
from repro.core.engine import NeedleTailEngine
from repro.core.multi_query import BatchQuery
from repro.data.block_store import Table, build_block_store
from repro.data.synthetic import make_clustered_table
from repro.storage import (
    CostAwarePolicy, RecencyPolicy, Tier, TierStack, make_tier_stack,
)

pytestmark = pytest.mark.serving

RPB = 64
NB = RPB * (4 * 4 + 2 * 4 + 1)  # slab bytes of the 4-dim/2-measure tables


def _make_table(kind: str, seed: int, n: int = 6_000) -> Table:
    rng = np.random.default_rng(seed)
    if kind == "clustered":
        return make_clustered_table(num_records=n, num_dims=4, density=0.15,
                                    seed=seed, mean_cluster=48)
    if kind == "uniform":
        return Table(
            dims=rng.integers(0, 3, (n, 4)).astype(np.int32),
            measures=rng.normal(size=(n, 2)).astype(np.float32),
            cards=np.asarray([3, 3, 3, 3]),
        )
    if kind == "skewed":
        dims = np.zeros((n, 4), np.int32)
        dims[: n // 10, 0] = 1
        dims[:, 1] = rng.integers(0, 2, n)
        dims[:, 2] = (np.arange(n) // RPB) % 3
        dims[:, 3] = rng.integers(0, 3, n)
        return Table(
            dims=dims,
            measures=rng.normal(size=(n, 2)).astype(np.float32),
            cards=np.asarray([2, 2, 3, 3]),
        )
    raise ValueError(kind)


_STORES: dict = {}


def _store(kind: str, seed: int):
    key = (kind, seed)
    if key not in _STORES:
        _STORES[key] = build_block_store(_make_table(kind, seed), RPB)
    return _STORES[key]


QUERY_POOL = [
    ([(0, 1)], 40, "and"),
    ([(0, 1), (1, 1)], 120, "and"),
    ([(1, 1), (2, 1)], 60, "or"),
    ([(2, 0)], 25, "and"),
    ([(0, 1), (2, 1), (3, 1)], 200, "and"),
    ([(3, 1), (1, 0)], 90, "or"),
]


def _queries(spec) -> list[BatchQuery]:
    return [BatchQuery(p, k, op) for (p, k, op) in spec]


def _assert_result_equal(a, b):
    np.testing.assert_array_equal(a.record_block, b.record_block)
    np.testing.assert_array_equal(a.record_row, b.record_row)
    np.testing.assert_array_equal(a.measures, b.measures)
    np.testing.assert_array_equal(a.blocks_fetched, b.blocks_fetched)
    assert a.plan_rounds == b.plan_rounds
    assert a.algo == b.algo


def _assert_batch_equal(a, b):
    assert len(a.results) == len(b.results)
    for ra, rb in zip(a.results, b.results):
        _assert_result_equal(ra, rb)


def _stack_config(name: str) -> TierStack:
    """Named tier configs the equivalence property sweeps over."""
    # budgets are real slab bytes; the cost presets keep their default
    # 256 KB block (at the test's 1.6 KB slabs the hbm DMA-issue latency
    # would exceed dram's access latency and honestly invert the ladder)
    if name == "roomy":  # everything fits everywhere
        return make_tier_stack(None, None)
    if name == "tiny_hbm":  # tier-0 pressure: cost-aware spill to dram
        return make_tier_stack(3 * NB, None)
    if name == "tiny_both":  # total budget under the working set: drops
        return make_tier_stack(2 * NB, 3 * NB)
    if name == "recency":  # pure recency: every block enters tier 0, cascades
        return make_tier_stack(3 * NB, 5 * NB, policy=RecencyPolicy())
    if name == "device_fill":  # tier-0 filled through the Pallas union gather
        return make_tier_stack(4 * NB, None, device_fill=True)
    raise ValueError(name)


# ---------------------------------------------------------------------------
# Satellite: the `dram` preset and the preset cost ladder.
# ---------------------------------------------------------------------------
def test_cost_model_preset_consistency():
    """Every preset is self-consistent and the tier ladder is strict:
    hbm < dram < ici < ssd < hdd on far_cost AND on a scattered fetch."""
    ladder = ["hbm", "dram", "ici", "ssd", "hdd"]
    scattered = np.asarray([0, 97, 311, 1024, 4097])
    costs = []
    for kind in ladder:
        cm = make_cost_model(kind)
        assert cm.name == kind
        assert 0 < cm.seq_cost <= cm.far_cost
        assert cm.first_block_cost > 0 and cm.max_dist >= 1
        # the curve interpolates seq -> far and never exceeds the far seek
        d = np.arange(1, cm.max_dist + 1)
        near = np.asarray(cm.curve(d), dtype=np.float64)
        assert np.all(np.diff(near) >= -1e-12)  # non-decreasing in distance
        assert near[0] == pytest.approx(cm.seq_cost)
        assert np.all(near <= cm.far_cost + 1e-12)
        assert cm.rand_io(0, cm.max_dist + 10) == pytest.approx(cm.far_cost)
        assert cm.io_time([]) == 0.0
        assert cm.io_time([5]) == pytest.approx(cm.first_block_cost)
        costs.append((cm.far_cost, cm.io_time(scattered)))
    fars, ios = zip(*costs)
    assert list(fars) == sorted(fars) and len(set(fars)) == len(fars)
    assert list(ios) == sorted(ios) and len(set(ios)) == len(ios)


# ---------------------------------------------------------------------------
# Property: flat-cache oracle == every tiered config, per query and per
# batch, across layouts / ops / algos — including warm repeats and pressure.
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(
    st.sampled_from(("clustered", "uniform", "skewed")),
    st.integers(0, 2),
    st.sampled_from(("threshold", "two_prong", "auto")),
    st.sampled_from(("roomy", "tiny_hbm", "tiny_both", "recency", "device_fill")),
    st.lists(st.sampled_from(QUERY_POOL), min_size=1, max_size=4),
)
def test_tiered_equivalence_to_flat_oracle(kind, seed, algo, config, spec):
    store = _store(kind, seed)
    queries = _queries(spec)
    ref = NeedleTailEngine(store, cache_bytes=0)  # the flat-cache oracle
    ref_batch = ref.any_k_batch(queries, algo=algo)
    ref_seq = [ref.any_k(q.predicates, q.k, op=q.op, algo=algo) for q in queries]

    stack = _stack_config(config)
    eng = NeedleTailEngine(store, tiers=stack)
    cold = eng.any_k_batch(queries, algo=algo)
    _assert_batch_equal(cold, ref_batch)
    assert cold.tier_stats is not None  # the per-tier ledger is threaded
    for q, r in zip(queries, ref_seq):
        _assert_result_equal(eng.any_k(q.predicates, q.k, op=q.op, algo=algo), r)

    warm = eng.any_k_batch(queries, algo=algo)
    _assert_batch_equal(warm, ref_batch)
    uniq = int(cold.unique_blocks_fetched.size)
    if config in ("roomy", "tiny_hbm", "device_fill"):
        # an unbounded host tier holds the whole working set: the warm wave
        # is served from tiers 0-1 with ZERO backing-store reads
        assert warm.store_blocks_fetched == 0
        assert stack.stats.evictions == 0  # demote, never drop
    if config == "recency" and uniq > 3:
        # recency admits everything to tier 0: pressure MUST cascade down
        tc = stack.tier_counters()
        assert tc["hbm.demotions_out"] > 0
        assert tc["dram.demotions_in"] == tc["hbm.demotions_out"]
    # a budget-constrained third pass stays byte-identical regardless
    _assert_batch_equal(eng.any_k_batch(queries, algo=algo), ref_batch)


# ---------------------------------------------------------------------------
# Property: append invalidation evicts the dirtied tail from EVERY tier.
# ---------------------------------------------------------------------------
@settings(max_examples=6, deadline=None)
@given(
    st.sampled_from(("clustered", "uniform")),
    st.integers(0, 2),
    st.integers(1, 400),
    st.lists(st.sampled_from(QUERY_POOL), min_size=1, max_size=3),
)
def test_append_invalidates_all_tiers(kind, seed, n_extra, spec):
    base = _make_table(kind, seed)
    extra_full = _make_table(kind, seed + 100)
    extra = Table(
        dims=extra_full.dims[:n_extra],
        measures=extra_full.measures[:n_extra],
        cards=base.cards,
    )
    store = build_block_store(base, RPB)
    stack = make_tier_stack(4 * NB, None)
    eng = NeedleTailEngine(store, tiers=stack)
    queries = _queries(spec)
    eng.any_k_batch(queries, algo="auto")
    # force the trailing partial block resident in BOTH tiers' reach
    eng.block_cache.ensure(store, np.arange(store.num_blocks))

    first_touched = store.num_records // RPB
    grown = eng.append(extra)
    for b in range(first_touched, grown.num_blocks):
        for tier in stack.tiers:  # a stale copy in ANY tier would be a bug
            assert b not in tier
    assert stack.stats.invalidations > 0

    ref = NeedleTailEngine(grown, cache_bytes=0)
    for algo in ("threshold", "auto"):
        _assert_batch_equal(
            eng.any_k_batch(queries, algo=algo),
            ref.any_k_batch(queries, algo=algo),
        )


@pytest.mark.parametrize("mode", ["flat", "tiered"])
def test_append_rereads_not_counted_as_misses(mode):
    """Re-reading blocks evicted by append invalidation books under
    ``invalidation_rereads``, NOT ``misses`` — a warm cache that just
    absorbed an append must not look cold to the cost model / bench gates
    (counter-drift regression guard, flat LRU and tier stack alike)."""
    base = _make_table("clustered", 3)
    extra_full = _make_table("clustered", 103)
    extra = Table(dims=extra_full.dims[: 2 * RPB],
                  measures=extra_full.measures[: 2 * RPB],
                  cards=base.cards)
    store = build_block_store(base, RPB)
    if mode == "tiered":
        eng = NeedleTailEngine(store, tiers=make_tier_stack(4 * NB, None))
    else:
        eng = NeedleTailEngine(store)
    cache = eng.block_cache
    cache.ensure(store, np.arange(store.num_blocks))

    first_touched = store.num_records // RPB
    grown = eng.append(extra)
    touched = np.arange(first_touched, grown.num_blocks)

    misses0 = cache.stats.misses
    rereads0 = cache.stats.invalidation_rereads
    cache.ensure(grown, touched)
    assert cache.stats.invalidation_rereads - rereads0 == touched.size
    assert cache.stats.misses == misses0, (
        "append-invalidation re-reads inflated the cold-miss counter")

    # one-shot marks: the blocks are resident again, a repeat is pure hits
    misses1, rereads1 = cache.stats.misses, cache.stats.invalidation_rereads
    cache.ensure(grown, touched)
    assert (cache.stats.misses, cache.stats.invalidation_rereads) \
        == (misses1, rereads1)

    ref = NeedleTailEngine(grown, cache_bytes=0)
    queries = _queries(QUERY_POOL[:3])
    _assert_batch_equal(eng.any_k_batch(queries, algo="auto"),
                        ref.any_k_batch(queries, algo="auto"))


# ---------------------------------------------------------------------------
# Device pipeline under a tiny tier-0 budget: byte-identity + transfer ledger.
# ---------------------------------------------------------------------------
@pytest.mark.device
def test_device_pipeline_rounds_on_tiered_storage():
    store = _store("clustered", 1)
    queries = _queries(QUERY_POOL[:4])
    ref = NeedleTailEngine(store, cache_bytes=0)
    ref_batch = ref.any_k_batch(queries, algo="auto")

    stack = make_tier_stack(2 * NB, None, device_fill=True)
    eng = NeedleTailEngine(store, tiers=stack)
    cold = eng.any_k_batch(queries, algo="auto", device=True)
    _assert_batch_equal(cold, ref_batch)
    assert cold.device_transfers <= cold.rounds + 1  # the ≤1/round ledger
    warm = eng.any_k_batch(queries, algo="auto", device=True)
    _assert_batch_equal(warm, ref_batch)
    assert warm.store_blocks_fetched == 0  # served from tiers 0-1
    assert warm.device_transfers <= warm.rounds + 1
    assert stack.stats.evictions == 0  # tier-0 pressure demoted, not dropped
    tc = stack.tier_counters()
    assert tc["hbm.demotions_out"] > 0 and tc["dram.demotions_in"] > 0


def test_get_device_serves_tier0_residency():
    store = _store("clustered", 0)
    stack = make_tier_stack(None, None, device_fill=True)
    ids = np.asarray([0, 3, 7, 2])
    dd, dm, dv = stack.get_device(store, ids)
    bd, bm, bv = store.fetch(ids)
    np.testing.assert_array_equal(np.asarray(dd), bd)
    np.testing.assert_array_equal(np.asarray(dm), bm)
    np.testing.assert_array_equal(np.asarray(dv), bv)
    assert all(int(b) in stack.tiers[0] for b in ids)
    # device gathers are logical accesses: they feed the hit ledger and the
    # policy's frequency scores (promotion eligibility, victim protection)
    h0 = stack.tiers[0].stats.hits
    stack.get_device(store, ids)
    assert stack.tiers[0].stats.hits == h0 + ids.size
    assert all(stack.accesses(int(b)) == 2 for b in ids)


def test_host_gather_of_device_slab_memoizes_one_download():
    """A device-tier resident serves host gathers through a memoized host
    mirror: one device→host download per residency, not one per access —
    and the mirror dies with the slab."""
    store = _store("clustered", 0)
    stack = make_tier_stack(None, None, device_fill=True)
    ids = np.asarray([1, 4])
    first = stack.get_many(store, ids)
    ref = store.fetch(ids)
    for got, want in zip(first, ref):
        np.testing.assert_array_equal(got, want)
    m1 = stack.tiers[0].host_view(1)
    assert m1 is not None
    again = stack.get_many(store, ids)
    for got, want in zip(again, ref):
        np.testing.assert_array_equal(got, want)
    assert stack.tiers[0].host_view(1) is m1  # same mirror object: no re-download
    stack.invalidate([1])
    assert stack.tiers[0]._host_mirror.get(1) is None


# ---------------------------------------------------------------------------
# Placement mechanics: cost-aware admission / promotion / victim selection.
# ---------------------------------------------------------------------------
def test_cost_aware_promotion_displaces_weakest_incumbent():
    """A hot lower-tier block out-scores a cold tier-0 incumbent (same Δcost
    and slab size, so the io_time-saved-per-byte comparison reduces to
    access frequency) and takes its slot; the incumbent demotes, not drops."""
    store = _store("uniform", 0)
    stack = make_tier_stack(2 * NB, None,
                            policy=CostAwarePolicy(promote_after=2))
    # blocks 0,1 fill tier 0 (admitted to free fast capacity)...
    stack.get_many(store, np.asarray([0, 1]))
    assert 0 in stack.tiers[0] and 1 in stack.tiers[0]
    # ...block 2 admits to dram (tier 0 full), then gets hot
    stack.get_many(store, np.asarray([2]))
    assert 2 in stack.tiers[1]
    for _ in range(4):
        stack.get_many(store, np.asarray([2]))
    assert 2 in stack.tiers[0]  # promoted past the cold incumbents
    assert (0 in stack.tiers[1]) or (1 in stack.tiers[1])  # demoted, resident
    assert stack.stats.evictions == 0
    tc = stack.tier_counters()
    assert tc["hbm.promotions_in"] == 1 and tc["hbm.demotions_out"] == 1


def test_demotion_into_a_too_small_tier_is_counted_as_a_drop():
    """A 'demotion' whose every lower tier is too small for the slab leaves
    the stack — the ledger must record an eviction, not a phantom arrival
    (the demote-not-drop CI guard trusts these counters)."""
    store = _store("uniform", 0)
    stack = make_tier_stack(2 * NB, NB // 2, policy=RecencyPolicy())
    stack.get_many(store, np.asarray([0, 1]))
    ref = store.fetch(np.asarray([0, 1, 2]))
    out = stack.get_many(store, np.asarray([2]))  # displaces the tier-0 LRU
    np.testing.assert_array_equal(out[0], ref[0][2:])
    tc = stack.tier_counters()
    assert stack.stats.evictions == 1  # the displaced block really dropped
    assert tc["hbm.evictions"] == 1 and tc["hbm.demotions_out"] == 0
    assert tc["dram.demotions_in"] == 0 and len(stack.tiers[1]) == 0
    # and the data path stays exact regardless
    again = stack.get_many(store, np.asarray([0, 1, 2]))
    for got, want in zip(again, ref):
        np.testing.assert_array_equal(got, want)


def test_promotion_into_a_too_small_tier_is_not_ledgered():
    """A policy without its own fits_at_all guard (pure recency) promoting
    into a tier that cannot hold one slab must be a no-op — not a pop and
    re-insert into the SAME tier recorded as a phantom promotion."""
    store = _store("uniform", 0)
    stack = make_tier_stack(NB // 2, None, policy=RecencyPolicy())
    ref = store.fetch(np.asarray([0, 1]))
    for _ in range(3):
        out = stack.get_many(store, np.asarray([0, 1]))
        for got, want in zip(out, ref):
            np.testing.assert_array_equal(got, want)
    tc = stack.tier_counters()
    assert len(stack.tiers[0]) == 0  # nothing can ever reside in tier 0
    assert tc["dram.promotions_in"] == 0 and tc["hbm.promotions_in"] == 0
    assert tc["dram.hits"] == 4  # the warm repeats really were hits


def test_inverted_cost_ladder_never_promotes():
    """A 'fast' tier that is actually slower than the level below offers no
    io_time saving — the cost-aware arbiter must refuse to promote into it
    and must not admit fresh blocks there."""
    slow_top = TierStack(
        tiers=[
            Tier("slow", 4 * NB, make_cost_model("hdd", NB)),
            Tier("fast", None, make_cost_model("dram", NB)),
        ],
        backing=make_cost_model("hdd", NB),
        policy=CostAwarePolicy(promote_after=1),
    )
    store = _store("uniform", 1)
    for _ in range(3):
        slow_top.get_many(store, np.asarray([0, 1, 2]))
    tc = slow_top.tier_counters()
    assert tc["slow.promotions_in"] == 0 and tc["slow.admissions"] == 0
    assert len(slow_top.tiers[0]) == 0 and len(slow_top.tiers[1]) == 3


def test_effective_io_time_prices_by_residency():
    store = _store("uniform", 2)
    stack = make_tier_stack(2 * NB, None)
    backing = stack.backing
    ids = np.asarray([0, 1, 2, 3])
    cold = stack.effective_io_time(ids)
    assert cold == pytest.approx(backing.io_time(ids))
    stack.ensure(store, ids)
    warm = stack.effective_io_time(ids)
    # resident blocks price at µs-scale tier models, not the ms-scale store
    assert warm < cold / 100
    # a disjoint cold set still prices at the backing model
    assert stack.effective_io_time([10, 11]) == pytest.approx(
        backing.io_time([10, 11])
    )


def _qerr(got: float, want: float) -> float:
    q = got / want
    return max(q, 1.0 / q)


def test_effective_io_time_calibrated_mixed_residency():
    """Calibration refits BOTH components effective_io_time composes: a
    mixed warm/cold set prices as a fitted-dram pass over the residents plus
    a fitted-backing pass over the misses — dedup and the ``backing=``
    override behave exactly as on the preset path."""
    from repro.storage import SyntheticTimingBackend

    store = _store("uniform", 3)
    # hbm budget 0: nothing ever fits tier 0, residents land in dram
    stack = make_tier_stack(0, None, backing="ssd", block_bytes=NB)
    truth_ssd = make_cost_model("hdd", NB)  # the "ssd" really seeks like HDD
    truth_dram = make_cost_model("dram", 5 * NB)  # host copies 5x slower
    fitted = stack.calibrate(
        SyntheticTimingBackend({"ssd": truth_ssd, "dram": truth_dram}))
    assert stack.backing is fitted["ssd"]
    assert stack.tiers[1].cost is fitted["dram"]
    stack.ensure(store, np.asarray([0, 1]))
    assert list(stack.residency_tier(np.asarray([0, 1, 7, 11]))) == [1, 1, 2, 2]
    mixed = stack.effective_io_time([0, 1, 7, 11])
    expect = fitted["dram"].io_time([0, 1]) + fitted["ssd"].io_time([7, 11])
    assert mixed == pytest.approx(expect)
    # the fitted components track the deviating truth, not the old presets
    assert _qerr(fitted["dram"].io_time([0, 1]), truth_dram.io_time([0, 1])) < 1.5
    assert _qerr(fitted["ssd"].io_time([7, 11]), truth_ssd.io_time([7, 11])) < 1.5
    # dedup survives the calibrated mixed-residency path, in any order
    assert stack.effective_io_time([0, 0, 1, 7, 7, 11]) == pytest.approx(mixed)
    assert stack.effective_io_time([1, 0, 11, 7, 1]) == pytest.approx(mixed)
    # `backing=` override prices the cold run under the caller's model
    slow = make_cost_model("hdd", NB)
    assert stack.effective_io_time([7, 11], backing=slow) == pytest.approx(
        slow.io_time([7, 11]))


def test_effective_io_time_applies_ledger_corrections():
    """Between recalibrations, the plan ledger's committed q-error
    correction scales each level's component — misses under the backing's
    multiplier, residents under their own tier's, an override under the
    override level's (none recorded → uncorrected)."""
    from repro.core.plan_ledger import PlanLedger

    store = _store("uniform", 4)
    stack = make_tier_stack(0, None, backing="hdd", block_bytes=NB)
    stack.ledger = PlanLedger()
    ids = [3, 4, 9]
    base = stack.effective_io_time(ids)
    stack.ledger.record("placement", "hdd", 1.0, 4.0)
    corr = stack.ledger.correction("hdd")
    assert corr == pytest.approx(4.0)
    assert stack.effective_io_time(ids) == pytest.approx(base * corr)
    stack.ensure(store, np.asarray([3]))
    # the demand fetch itself recorded a (wall-clock) placement observation,
    # so re-read the committed multiplier before composing the expectation
    corr2 = stack.ledger.correction("hdd")
    expect = (stack.tiers[1].cost.io_time([3])
              + stack.backing.io_time([4, 9]) * corr2)
    assert stack.effective_io_time(ids) == pytest.approx(expect)
    ssd = make_cost_model("ssd", NB)
    assert stack.effective_io_time([4, 9], backing=ssd) == pytest.approx(
        ssd.io_time([4, 9]))


def test_effective_io_time_prices_peer_hop_with_fitted_ici():
    """A peer-resident block prices at the interconnect hop, and a model
    fitted from measured link timings (4x slower than the ``ici`` preset)
    overrides the preset through ``make_peer_stack(ici_cost=...)``."""
    from repro.storage import (
        PeerGroup, SyntheticTimingBackend, calibrate_model, make_peer_stack,
    )

    store = _store("uniform", 5)
    truth_ici = make_cost_model("ici", 4 * NB)
    fitted_ici = calibrate_model(
        SyntheticTimingBackend({"ici": truth_ici}), "ici",
        base=make_cost_model("ici", NB))
    group = PeerGroup(store, 2)
    local = make_peer_stack(group, 0, block_bytes=NB, ici_cost=fitted_ici)
    remote = make_peer_stack(group, 1, block_bytes=NB)
    remote.get_many(store, np.asarray([42]))  # shard 1 owns block 42
    peer_idx = local.tiers.index(local.peer_tier)
    assert local.residency_tier(np.asarray([42]))[0] == peer_idx
    got = local.effective_io_time([42])
    assert got == pytest.approx(fitted_ici.io_time([42]))
    assert _qerr(got, truth_ici.io_time([42])) < 1.5
    # the hop is priced dearer than the preset assumed, cheaper than a seek
    assert got > make_cost_model("ici", NB).io_time([42])
    assert got < local.backing.io_time([42])


def test_residency_aware_auto_prefers_resident_plan():
    """The §7.2 arbitration flip: cold, THRESHOLD's two far blocks beat the
    13-block TWO-PRONG window; with the window resident in tiers and the
    effective cost model in play, the window wins."""
    n_blocks = 60
    dims = np.zeros((n_blocks * RPB, 1), np.int32)
    for b in (0, 50):  # two fully-dense far-apart blocks
        dims[b * RPB:(b + 1) * RPB] = 1
    for b in range(10, 31):  # a long half-dense run: 10 matching rows each
        dims[b * RPB: b * RPB + 10] = 1
    table = Table(
        dims=dims,
        measures=np.arange(dims.shape[0], dtype=np.float32)[:, None],
        cards=np.asarray([2]),
    )
    store = build_block_store(table, RPB)
    k = 128  # needs density mass 2.0: {0, 50} or ~13 blocks of the run

    flat = NeedleTailEngine(store)  # backing-model arbitration (the paper)
    plan_flat, algo_flat = flat.plan([(0, 1)], k, algo="auto")
    assert algo_flat == "threshold" and set(plan_flat) == {0, 50}

    stack = make_tier_stack(None, None)
    aware = NeedleTailEngine(store, tiers=stack, residency_aware=True)
    stack.ensure(store, np.arange(10, 31))  # the run is resident, {0,50} cold
    plan_aware, algo_aware = aware.plan([(0, 1)], k, algo="auto")
    assert algo_aware == "two_prong"  # the resident window beats 2 cold seeks
    assert set(plan_aware) <= set(range(10, 31))
    # the chosen plan still answers the query: the window really holds >= k
    r = aware.any_k([(0, 1)], k, algo="auto")
    assert r.num_records >= k
    assert np.all(table.dims[r.record_block * RPB + r.record_row, 0] == 1)


# ---------------------------------------------------------------------------
# Residency-aware admission: fully-resident waves launch before the SLO.
# ---------------------------------------------------------------------------
class _SimClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_admission_launches_resident_wave_early():
    from repro.serving.admission import AdmissionController, AdmissionPolicy
    from repro.storage.residency import make_residency_probe

    store = _store("clustered", 0)
    stack = make_tier_stack(None, None)
    eng = NeedleTailEngine(store, tiers=stack)
    hot = _queries(QUERY_POOL[:3])
    eng.any_k_batch(hot, algo="auto")  # warm the plan memo + tiers

    clk = _SimClock()
    adm = AdmissionController(
        AdmissionPolicy(slo_s=100.0, max_wave=8),
        clock=clk,
        residency_probe=make_residency_probe(eng),
    )
    for q in hot:
        adm.submit(q)
    wave = adm.poll()  # SLO is an eternity away; residency launches it NOW
    assert wave is not None and len(wave) == 3
    assert adm.stats.resident_waves == 1
    batch = eng.any_k_batch(wave, algo="auto")
    assert batch.store_blocks_fetched == 0  # the promised zero-I/O wave

    # a never-seen template is not memoized: the probe refuses, the wave
    # accumulates until its deadline like any cold wave
    adm.submit(BatchQuery([(1, 1), (3, 1)], 33, "and"))
    assert adm.poll() is None
    clk.t = 200.0
    wave = adm.poll()
    assert wave is not None and adm.stats.deadline_waves == 1


def test_residency_probe_serves_mesh_attached_engines():
    """A mesh-attached engine's waves feed the sharded-THRESHOLD memo, not
    the host sorted-order memo — the probe must peek that one instead."""
    import jax

    from repro.storage.residency import wave_is_resident

    store = _store("clustered", 1)
    stack = make_tier_stack(None, None)
    eng = NeedleTailEngine(store, tiers=stack)
    eng.attach_mesh(jax.make_mesh((1,), ("data",)))
    hot = _queries(QUERY_POOL[:2])
    assert not wave_is_resident(eng, hot)  # nothing memoized yet
    eng.any_k_batch(hot, algo="auto")  # sharded plan wave warms memo + tiers
    assert eng.plan_cache.stats.threshold_misses == 0  # host memo untouched
    assert wave_is_resident(eng, hot)
    batch = eng.any_k_batch(hot, algo="auto")
    assert batch.store_blocks_fetched == 0


def test_serve_engine_residency_wiring():
    """ServeEngine(exemplar_residency=True) installs the probe on its
    controller and last_wave_stats carries the per-tier placement ledger."""
    import itertools

    from repro.serving.admission import AdmissionController, AdmissionPolicy
    from repro.serving.engine import ServeEngine

    store = _store("clustered", 2)
    stack = make_tier_stack(None, None)
    eng = NeedleTailEngine(store, tiers=stack)
    hot = _queries(QUERY_POOL[:2])
    eng.any_k_batch(hot, algo="auto")

    clk = _SimClock()
    serve = ServeEngine.__new__(ServeEngine)  # no LM needed for exemplars
    serve.max_slots = 8
    serve.exemplar_residency = True
    serve.exemplar_admission = AdmissionController(
        AdmissionPolicy(slo_s=100.0, max_wave=8), clock=clk
    )
    serve._rid = itertools.count()
    for p, k, op in QUERY_POOL[:2]:
        serve.submit_exemplar_request(p, k, op)
    done = serve.pump_exemplar_requests(eng)  # far SLO: residency launches
    assert len(done) == 2 and all(r.done for r in done)
    assert serve.exemplar_admission.stats.resident_waves == 1
    stats = serve.last_wave_stats
    assert stats["store_blocks_fetched"] == 0
    assert stats["tiers"] is not None
    assert stats["tiers"]["hbm.hits"] + stats["tiers"]["dram.hits"] > 0


# ---------------------------------------------------------------------------
# The sharded fetch path: ici-priced remote fetches through the tier stack.
# ---------------------------------------------------------------------------
def test_distributed_fetch_prices_remote_blocks_with_ici():
    import jax

    from repro.core.sharded import DistributedAnyK

    store = _store("clustered", 1)
    stack = make_tier_stack(None, None)
    eng = NeedleTailEngine(store, tiers=stack)
    mesh = jax.make_mesh((1,), ("data",))
    dist = DistributedAnyK(
        mesh, records_per_block=RPB, candidates=store.num_blocks,
        block_cache=eng.block_cache,
    )
    assert dist.remote_cost.name == "ici"
    comb = eng.combined_density([(0, 1)])
    plan = dist.threshold_plan(np.asarray(comb, np.float32), 64.0)
    ids, bd, bm, bv = dist.fetch_plan(store, plan)
    ref = store.fetch(ids)
    np.testing.assert_array_equal(bd, ref[0])
    np.testing.assert_array_equal(bm, ref[1])
    np.testing.assert_array_equal(bv, ref[2])
    cold_io = dist.last_fetch_io_s
    assert cold_io == pytest.approx(dist.remote_cost.io_time(ids))
    dist.fetch_plan(store, plan)  # now tier-resident: effective price drops
    assert dist.last_fetch_io_s < cold_io
    assert all(int(b) in eng.block_cache for b in ids)
