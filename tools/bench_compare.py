"""Compare two ``BENCH_*.json`` trees and flag metric regressions.

Every benchmark section persists its headline numbers through
``benchmarks.common.write_bench_json`` — the per-PR perf trajectory lives
at the repo root as ``BENCH_<section>.json``.  This tool diffs two such
trees (typically: the checkout before and after a change)::

    PYTHONPATH=src python -m tools.bench_compare BASE_DIR NEW_DIR
    PYTHONPATH=src python -m tools.bench_compare BASE_DIR NEW_DIR --tolerance 0.2
    PYTHONPATH=src python -m tools.bench_compare --gate    # vs HEAD baselines
    PYTHONPATH=src python -m tools.bench_compare --smoke   # self-check

``--gate`` is the cross-PR regression gate: every ``BENCH_*.json`` in the
working tree is diffed against the copy checked in at ``HEAD`` (via ``git
show``).  Files with no committed baseline are skipped (new benchmarks),
pairs whose ``config.*`` leaves differ are INCOMPARABLE and skipped (a
smoke rerun of a full baseline is not a regression), and the gate exits 1
only when a metric moved in its bad direction by more than ``--tolerance``
(default 0.15, i.e. a >15% p99 regression fails).

Each JSON payload is flattened to dotted numeric leaves
(``continuous.p99_ms``, ``remote_wave.batch_ms``, ...); the ``run_meta``
block stamped by ``write_bench_json`` is metadata, not a metric, and is
skipped.  Whether a change is a *regression* depends on the metric's
direction, inferred from its name:

* **lower is better** — durations (``*_s``, ``*_ms``, ``time``, ``wait``,
  ``latency``, ``p50/p95/p99``), I/O volumes (``io_*``, ``blocks``,
  ``reads``, ``fetched``, ``misses``, ``transfers``), and error measures
  (``error``, ``qerror``, ``violations``, ``halfwidth``);
* **higher is better** — ``rate``, ``hit``, ``throughput``, ``qps``,
  ``attainment``, ``speedup``, ``samples``, ``occupancy``;
* anything else is reported only when it changes, never as a regression
  (configuration echoes like ``config.rpb`` must match exactly or the
  pair is flagged as *incomparable* instead).

A metric regresses when it moves in the bad direction by more than
``--tolerance`` (relative, default 0.15 — wall-clock numbers jitter).
Exit status 1 on any regression, 0 otherwise; ``--smoke`` (wired into the
driver as ``python -m benchmarks.run --only bench_compare``) asserts a
self-diff of the repo's own tree is clean and that a synthetically
injected 2x regression in a temp copy IS flagged.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# Name fragments that decide a metric's direction.  Checked on the last
# dotted component, suffix fragments first (``_s`` must not match ``hits``).
_LOWER_SUFFIXES = ("_s", "_ms", "_mb")
_LOWER_PARTS = (
    "time", "io_", "_io", "p99", "p95", "p50", "latency", "wait", "error",
    "blocks", "reads", "fetched", "misses", "qerror", "violations",
    "transfers", "halfwidth", "seeks", "drops", "evictions",
)
_HIGHER_PARTS = (
    "rate", "hit", "throughput", "qps", "attainment", "speedup", "samples",
    "occupancy", "density", "dedup",
)
# leaves under these dotted prefixes are configuration, not metrics: they
# must be EQUAL for the comparison to be meaningful at all
_CONFIG_PREFIXES = ("config.", "run_meta.")


def direction(key: str) -> str:
    """'lower' | 'higher' | 'neutral' for a flattened metric key."""
    leaf = key.rsplit(".", 1)[-1].lower()
    if any(leaf.endswith(s) for s in _LOWER_SUFFIXES):
        return "lower"
    if any(p in leaf for p in _LOWER_PARTS):
        return "lower"
    if any(p in leaf for p in _HIGHER_PARTS):
        return "higher"
    return "neutral"


def flatten(payload, prefix: str = "") -> dict[str, float]:
    """Numeric leaves of a bench payload as ``{dotted.key: value}``.

    ``run_meta`` is skipped (metadata); booleans are skipped (flags, not
    metrics); lists index as ``key.0``, ``key.1``, ...
    """
    out: dict[str, float] = {}
    if isinstance(payload, dict):
        for k, v in sorted(payload.items()):
            if not prefix and k == "run_meta":
                continue
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(payload, list):
        for i, v in enumerate(payload):
            out.update(flatten(v, f"{prefix}{i}."))
    elif isinstance(payload, bool):
        pass
    elif isinstance(payload, (int, float)):
        out[prefix[:-1]] = float(payload)
    return out


def compare_payloads(base: dict, new: dict, tolerance: float) -> dict:
    """Diff two bench payloads; returns dict(regressions, improvements,
    changed, incomparable) where each entry is (key, base, new)."""
    fb, fn = flatten(base), flatten(new)
    regressions, improvements, changed, incomparable = [], [], [], []
    for key in sorted(set(fb) & set(fn)):
        b, n = fb[key], fn[key]
        if any(key.startswith(p) for p in _CONFIG_PREFIXES):
            if b != n:
                incomparable.append((key, b, n))
            continue
        if b == n:
            continue
        rel = (n - b) / max(abs(b), 1e-12)
        d = direction(key)
        if d == "lower" and rel > tolerance:
            regressions.append((key, b, n))
        elif d == "higher" and rel < -tolerance:
            regressions.append((key, b, n))
        elif d == "neutral":
            changed.append((key, b, n))
        elif abs(rel) > tolerance:
            improvements.append((key, b, n))
    return dict(regressions=regressions, improvements=improvements,
                changed=changed, incomparable=incomparable)


def compare_trees(base_dir: Path, new_dir: Path, tolerance: float) -> int:
    """Diff every BENCH_*.json present in both trees; prints a report and
    returns the number of regressions (0 = clean)."""
    base_files = {p.name: p for p in sorted(Path(base_dir).glob("BENCH_*.json"))}
    new_files = {p.name: p for p in sorted(Path(new_dir).glob("BENCH_*.json"))}
    common = sorted(set(base_files) & set(new_files))
    if not common:
        print(f"# no BENCH_*.json present in both {base_dir} and {new_dir}")
        return 0
    for name in sorted(set(base_files) ^ set(new_files)):
        side = "base" if name in base_files else "new"
        print(f"# {name}: only in {side} tree, skipped")
    total = 0
    for name in common:
        base = json.loads(base_files[name].read_text())
        new = json.loads(new_files[name].read_text())
        r = compare_payloads(base, new, tolerance)
        total += len(r["regressions"]) + len(r["incomparable"])
        status = "OK" if not (r["regressions"] or r["incomparable"]) else "REGRESSED"
        print(f"== {name}: {status} ({len(r['regressions'])} regressions, "
              f"{len(r['improvements'])} improvements, "
              f"{len(r['changed'])} neutral changes)")
        for key, b, n in r["incomparable"]:
            print(f"  INCOMPARABLE {key}: {b} != {n} (config/meta mismatch)")
        for key, b, n in r["regressions"]:
            print(f"  REGRESSION   {key}: {b} -> {n} "
                  f"({(n - b) / max(abs(b), 1e-12):+.1%}, "
                  f"{direction(key)}-is-better)")
        for key, b, n in r["improvements"]:
            print(f"  improvement  {key}: {b} -> {n}")
    return total


def gate(tolerance: float, repo: Path = REPO) -> int:
    """Diff the working tree's BENCH_*.json against the HEAD baselines.

    Returns the number of regressions (0 = clean).  Degrades gracefully:
    no git, no commits, or no committed baseline for a file all SKIP rather
    than fail — the gate only judges pairs it can actually compare, and a
    config mismatch (e.g. smoke rerun vs full baseline) is INCOMPARABLE,
    reported but never counted as a regression.
    """
    import subprocess

    new_files = sorted(Path(repo).glob("BENCH_*.json"))
    if not new_files:
        print(f"# gate: no BENCH_*.json under {repo}, nothing to check")
        return 0
    total = 0
    for p in new_files:
        try:
            proc = subprocess.run(
                ["git", "-C", str(repo), "show", f"HEAD:{p.name}"],
                capture_output=True, text=True, timeout=30)
        except (OSError, subprocess.TimeoutExpired) as exc:
            print(f"# gate: git unavailable ({exc}); skipping {p.name}")
            continue
        if proc.returncode != 0:
            print(f"# gate: {p.name}: no baseline at HEAD, skipped (new bench)")
            continue
        try:
            base = json.loads(proc.stdout)
            new = json.loads(p.read_text())
        except json.JSONDecodeError as exc:
            print(f"# gate: {p.name}: unparsable ({exc}), skipped")
            continue
        r = compare_payloads(base, new, tolerance)
        if r["incomparable"]:
            print(f"== {p.name} vs HEAD: INCOMPARABLE (run configs differ), "
                  "skipped")
            for key, b, n in r["incomparable"]:
                print(f"   {key}: {b} != {n}")
            continue
        status = "OK" if not r["regressions"] else "REGRESSED"
        print(f"== {p.name} vs HEAD: {status} "
              f"({len(r['regressions'])} regressions, "
              f"{len(r['improvements'])} improvements, "
              f"{len(r['changed'])} neutral changes)")
        for key, b, n in r["regressions"]:
            print(f"  REGRESSION   {key}: {b} -> {n} "
                  f"({(n - b) / max(abs(b), 1e-12):+.1%}, "
                  f"{direction(key)}-is-better)")
        total += len(r["regressions"])
    return total


def _smoke() -> None:
    """Self-check: the repo tree diffs clean against itself, and an
    injected 2x regression in a temp copy is flagged."""
    import shutil
    import tempfile

    assert compare_trees(REPO, REPO, tolerance=0.15) == 0, \
        "self-diff of the repo's own BENCH_*.json tree must be clean"

    victims = sorted(REPO.glob("BENCH_*.json"))
    assert victims, "no BENCH_*.json at the repo root to smoke-test against"
    with tempfile.TemporaryDirectory() as td:
        tmp = Path(td)
        for v in victims:
            shutil.copy(v, tmp / v.name)
        # inject a 2x regression into the first lower-is-better metric
        doc = json.loads(victims[0].read_text())
        flat = flatten(doc)
        key = next(k for k in sorted(flat)
                   if direction(k) == "lower" and flat[k] > 0
                   and not any(k.startswith(p) for p in _CONFIG_PREFIXES))
        node, path = doc, key.split(".")
        for part in path[:-1]:
            node = node[int(part)] if isinstance(node, list) else node[part]
        leaf = path[-1]
        if isinstance(node, list):
            node[int(leaf)] = node[int(leaf)] * 2
        else:
            node[leaf] = node[leaf] * 2
        (tmp / victims[0].name).write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
        flagged = compare_trees(REPO, tmp, tolerance=0.15)
        assert flagged >= 1, f"injected 2x regression on {key!r} was not flagged"

        # gate plumbing: a tree with no git history skips every file cleanly,
        # and the injected 2x regression IS caught when the doctored tree is
        # committed as its own HEAD baseline and then compared to the
        # original numbers
        assert gate(tolerance=0.15, repo=tmp) == 0, \
            "gate must skip (not fail) when no HEAD baseline exists"
        import subprocess
        env = {"GIT_AUTHOR_NAME": "bench", "GIT_AUTHOR_EMAIL": "b@e.nch",
               "GIT_COMMITTER_NAME": "bench", "GIT_COMMITTER_EMAIL": "b@e.nch",
               "HOME": td, "PATH": "/usr/bin:/bin:/usr/local/bin"}
        try:
            for cmd in (["git", "init", "-q"], ["git", "add", "-A"],
                        ["git", "commit", "-qm", "baseline"]):
                subprocess.run(cmd, cwd=td, env=env, check=True,
                               capture_output=True, timeout=30)
        except (OSError, subprocess.CalledProcessError, subprocess.TimeoutExpired):
            print("# bench-compare smoke: git unavailable, gate-catch leg skipped")
        else:
            # doctored numbers are now HEAD; restore the original file in the
            # working tree -> the doctored baseline shows a 2x IMPROVEMENT,
            # while overwriting with a further 2x bump flags a regression
            (tmp / victims[0].name).write_text(victims[0].read_text())
            assert gate(tolerance=0.15, repo=tmp) == 0, \
                "gate flagged an improvement as a regression"
            node2, doc2 = None, json.loads(victims[0].read_text())
            node2 = doc2
            for part in path[:-1]:
                node2 = node2[int(part)] if isinstance(node2, list) else node2[part]
            if isinstance(node2, list):
                node2[int(leaf)] = node2[int(leaf)] * 4
            else:
                node2[leaf] = node2[leaf] * 4
            (tmp / victims[0].name).write_text(
                json.dumps(doc2, indent=2, sort_keys=True) + "\n")
            assert gate(tolerance=0.15, repo=tmp) >= 1, \
                "gate missed a 4x bad-direction move vs its HEAD baseline"
    print(f"# bench-compare smoke ok: self-diff clean, injected 2x "
          f"regression on {key!r} flagged, gate skips/catches correctly")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("base", nargs="?", help="directory holding baseline BENCH_*.json")
    ap.add_argument("new", nargs="?", help="directory holding candidate BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="relative slack before a bad-direction move is a "
                         "regression (default 0.15)")
    ap.add_argument("--gate", action="store_true",
                    help="cross-PR regression gate: diff the working tree's "
                         "BENCH_*.json against the HEAD baselines (git show); "
                         "exit 1 on any >tolerance bad-direction move, skip "
                         "files without a committed baseline or with "
                         "mismatched run configs")
    ap.add_argument("--smoke", action="store_true",
                    help="self-check: repo tree diffs clean vs itself; an "
                         "injected 2x regression is flagged")
    args, _ = ap.parse_known_args(argv)
    if args.smoke:
        _smoke()
        return
    if args.gate:
        if gate(args.tolerance):
            raise SystemExit(1)
        return
    if not (args.base and args.new):
        ap.error("need BASE and NEW directories (or --smoke)")
    regressions = compare_trees(Path(args.base), Path(args.new), args.tolerance)
    if regressions:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
