"""Documentation guard: doctest the fenced examples in the markdown docs and
fail on broken cross-references into the source tree.

Checks, over ``README.md`` and ``docs/*.md``:

1. **Doctests** — every ```` ```python ```` fenced block containing ``>>>``
   is executed with :mod:`doctest`; any failure or example exception fails
   the run.  Blocks must be self-contained (do their own imports).
2. **Dotted references** — backticked names like
   ``repro.core.sharded.DistributedAnyK.any_k_batch`` are resolved: the
   longest importable module prefix is imported and the remaining components
   are walked with ``getattr``.  A rename (the very staleness this guard
   exists for — e.g. a doc still pointing at ``fetch_blocks`` after the
   method became ``fetch_plan``) fails the run.
3. **Path references** — backticked repo paths (``src/...``, ``tests/...``,
   ``benchmarks/...``, ``docs/...``, ``examples/...``, ``tools/...``) and
   relative markdown links must exist; ``*`` patterns must glob to at least
   one file.

Run standalone (``python -m tools.docs_check``), via the benchmark driver
(``python -m benchmarks.run --only docs``), or through tier-1 pytest
(``tests/test_docs.py``).  :func:`main` raises ``AssertionError`` on any
failure so the driver records it like a bench regression.
"""
from __future__ import annotations

import doctest
import glob as globmod
import importlib
import importlib.util
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)
_BACKTICK = re.compile(r"`([^`\n]+)`")
_DOTTED = re.compile(r"\b(?:repro|benchmarks|tools)(?:\.[A-Za-z_]\w*)+")
_PATHREF = re.compile(r"^(?:src|tests|benchmarks|docs|examples|tools)/[\w.*/-]+$")
_MDLINK = re.compile(r"\[[^\]]*\]\(([^)\s#]+)(?:#[^)]*)?\)")


def _doc_files() -> list[Path]:
    return [p for p in [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]
            if p.exists()]


def _run_doctests(path: Path, errors: list[str]) -> int:
    """Execute the doctest-style fenced blocks of one file; returns #blocks."""
    text = path.read_text()
    parser = doctest.DocTestParser()
    runner = doctest.DocTestRunner(verbose=False)
    n = 0
    for m in _FENCE.finditer(text):
        block = m.group(1)
        if ">>>" not in block:
            continue
        n += 1
        lineno = text[: m.start()].count("\n") + 1
        test = parser.get_doctest(
            block, {}, f"{path.name}:{lineno}", str(path), lineno
        )
        res = runner.run(test, clear_globs=True)
        if res.failed:
            errors.append(
                f"{path.name}:{lineno}: {res.failed}/{res.attempted} doctest "
                "example(s) failed (run `python -m tools.docs_check` for detail)"
            )
    return n


def _check_dotted(name: str, errors: list[str], where: str) -> None:
    parts = name.split(".")
    mod = None
    for cut in range(len(parts), 0, -1):
        prefix = ".".join(parts[:cut])
        try:
            if importlib.util.find_spec(prefix) is not None:
                mod = importlib.import_module(prefix)
                break
        except (ImportError, ModuleNotFoundError):
            continue
    if mod is None:
        errors.append(f"{where}: unresolvable module reference `{name}`")
        return
    obj = mod
    for attr in parts[cut:]:
        if not hasattr(obj, attr):
            errors.append(
                f"{where}: `{name}` — `{type(obj).__name__}` object "
                f"`{'.'.join(parts[:cut])}` has no attribute chain at `{attr}`"
            )
            return
        obj = getattr(obj, attr)


def _check_refs(path: Path, errors: list[str]) -> int:
    text = path.read_text()
    # blank out fenced code (examples are checked by doctest, not reference
    # rules) with equal newline counts so reported line numbers stay true
    prose = _FENCE.sub(lambda m: "\n" * m.group(0).count("\n"), text)
    n = 0
    for m in _BACKTICK.finditer(prose):
        span = m.group(1).strip()
        for dm in _DOTTED.finditer(span):
            where = f"{path.name}:{prose[: m.start()].count(chr(10)) + 1}"
            _check_dotted(dm.group(0), errors, where)
            n += 1
        if _PATHREF.match(span):
            n += 1
            where = f"{path.name}:{prose[: m.start()].count(chr(10)) + 1}"
            if "*" in span:
                if not globmod.glob(str(REPO / span)):
                    errors.append(f"{where}: path pattern `{span}` matches nothing")
            elif not (REPO / span).exists():
                errors.append(f"{where}: referenced path `{span}` does not exist")
    for m in _MDLINK.finditer(prose):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        n += 1
        where = f"{path.name}:{prose[: m.start()].count(chr(10)) + 1}"
        if not (path.parent / target).exists() and not (REPO / target).exists():
            errors.append(f"{where}: markdown link target `{target}` does not exist")
    return n


def main(argv=None) -> None:
    for p in (str(REPO), str(REPO / "src")):
        if p not in sys.path:
            sys.path.insert(0, p)
    errors: list[str] = []
    for path in _doc_files():
        nt = _run_doctests(path, errors)
        nr = _check_refs(path, errors)
        print(f"# {path.relative_to(REPO)}: {nt} doctest block(s), "
              f"{nr} cross-reference(s) checked")
    if errors:
        for e in errors:
            print(f"DOCS-CHECK FAIL: {e}", file=sys.stderr)
        raise AssertionError(f"docs-check: {len(errors)} error(s)")
    print("# docs-check ok")


if __name__ == "__main__":
    main()
