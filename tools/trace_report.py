#!/usr/bin/env python3
"""Render a serving trace (repro.obs JSONL export) as a human report.

Reads ONE artifact — the ``TraceRecorder.export_jsonl`` file — and needs no
live engine state: the event stream carries the whole request lifecycle
(queue wait → admission launch reason → per-round plan choice and predicted
io_time → fetch outcomes with predicted-vs-observed store io → device
transfers → completion), so the report reconstructs per-request critical
paths and per-wave summaries from the file alone.

Usage::

    python tools/trace_report.py TRACE.jsonl [--requests N]

Library surface (used by tests and the obs bench):

* :func:`load_events` — parse the JSONL.
* :func:`span_index` — spans by id (events reference their parent span).
* :func:`request_paths` — per-request critical path: submit/launch/done
  times, queue wait, launch reason, the tick spans the request rode in, and
  ``coverage`` (the fraction of its wall latency the trace accounts for).
* :func:`wave_summary` — per-span-name duration stats + plan/fetch rollups.
* :func:`render` — the text report.
"""
from __future__ import annotations

import argparse
import json
import math
from collections import defaultdict

TICK_SPANS = ("serve.exemplar_tick", "serve.aggregate_tick", "serve.lm_tick")


def load_events(path: str) -> list[dict]:
    """Parse a TraceRecorder JSONL export (one event per line)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def span_index(events: list[dict]) -> dict[int, dict]:
    """Spans by id.  Point events carry ``parent`` span ids; spans carry
    their own ``parent`` too, so this is the whole tree."""
    return {e["id"]: e for e in events if e["kind"] == "span"}


def _attrs(e: dict) -> dict:
    return e.get("attrs", {})


def _quantile(vals: list[float], q: float) -> float:
    if not vals:
        return 0.0
    vs = sorted(vals)
    return vs[max(0, min(len(vs) - 1, math.ceil(q * len(vs)) - 1))]


def _merge_overlap(intervals: list[tuple[float, float]],
                   lo: float, hi: float) -> float:
    """Total length of the union of `intervals` clipped to [lo, hi]."""
    clipped = sorted(
        (max(a, lo), min(b, hi)) for a, b in intervals if b > lo and a < hi
    )
    total, end = 0.0, lo
    for a, b in clipped:
        a = max(a, end)
        if b > a:
            total += b - a
            end = b
    return total


def request_paths(events: list[dict]) -> dict[int, dict]:
    """Reconstruct each request's critical path from the stream alone.

    Returns ``{rid: {kind, submit_t, launch_t, done_t, wait_s, reason,
    ticks, busy_s, wall_s, coverage}}``.  ``coverage`` is the fraction of
    the request's wall latency ([submit, done]) accounted for by its queue
    wait plus the union of serving-tick spans overlapping its seated window
    — the "does the span tree sum to the wall latency" number the obs bench
    gates on.  Requests still in flight at export (no ``request.done``) are
    omitted.
    """
    reqs: dict[int, dict] = {}
    tick_spans: list[tuple[float, float]] = []
    for e in events:
        if e["kind"] == "span" and e["name"] in TICK_SPANS:
            tick_spans.append((e["t0"], e["t1"]))
        if e["kind"] != "event":
            continue
        a = _attrs(e)
        if e["name"] == "request.submit":
            reqs[a["rid"]] = {"kind": a.get("kind"), "submit_t": e["t"]}
        elif e["name"] == "admission.launch":
            for rid, wait in zip(a.get("rids", []), a.get("waits_s", [])):
                r = reqs.get(rid)
                if r is not None and "launch_t" not in r:
                    r["launch_t"] = e["t"]
                    r["wait_s"] = wait
                    r["reason"] = a.get("reason")
        elif e["name"] == "request.done":
            r = reqs.get(a["rid"])
            if r is not None:
                r["done_t"] = e["t"]
                r["rounds"] = a.get("rounds")
    out: dict[int, dict] = {}
    for rid, r in reqs.items():
        if "done_t" not in r:
            continue  # still in flight at export
        sub, done = r["submit_t"], r["done_t"]
        launch = r.get("launch_t", sub)
        wall = done - sub
        r["ticks"] = sum(1 for a, b in tick_spans if b > launch and a < done)
        busy = (launch - sub) + _merge_overlap(tick_spans, launch, done)
        r["wall_s"] = wall
        r["busy_s"] = busy
        r["coverage"] = (busy / wall) if wall > 0 else 1.0
        r.setdefault("wait_s", launch - sub)
        r.setdefault("reason", None)
        out[rid] = r
    return out


def wave_summary(events: list[dict]) -> dict:
    """Per-span-name duration stats plus plan/fetch rollups."""
    durs: dict[str, list[float]] = defaultdict(list)
    choices: dict[str, int] = defaultdict(int)
    reasons: dict[str, int] = defaultdict(int)
    fetch = {"n_blocks": 0, "predicted_io_s": 0.0, "observed_io_s": 0.0}
    transfers = 0
    for e in events:
        a = _attrs(e)
        if e["kind"] == "span":
            durs[e["name"]].append(e["t1"] - e["t0"])
            if e["name"] == "plan.round":
                for algo, n in (a.get("choices") or {}).items():
                    choices[algo] += n
        elif e["name"] == "plan.round":  # device path emits events
            for algo, n in (a.get("choices") or {}).items():
                choices[algo] += n
        elif e["name"] == "admission.launch":
            reasons[a.get("reason", "?")] += 1
        elif e["name"] == "fetch.store":
            fetch["n_blocks"] += a.get("n", 0)
            fetch["predicted_io_s"] += a.get("predicted_io_s", 0.0)
            fetch["observed_io_s"] += a.get("observed_io_s", 0.0)
        elif e["name"] == "device.transfer":
            transfers += 1
    spans = {
        name: {
            "count": len(vs),
            "total_s": sum(vs),
            "p50_s": _quantile(vs, 0.50),
            "p99_s": _quantile(vs, 0.99),
        }
        for name, vs in sorted(durs.items())
    }
    return {
        "spans": spans,
        "plan_choices": dict(choices),
        "launch_reasons": dict(reasons),
        "store_fetch": fetch,
        "device_transfers": transfers,
    }


def render(events: list[dict], max_requests: int = 20) -> str:
    """The text report: per-request critical paths + per-wave summary."""
    paths = request_paths(events)
    summary = wave_summary(events)
    lines = [f"trace: {len(events)} events, {len(paths)} completed requests"]
    lines.append("")
    lines.append("requests (critical path):")
    lines.append(
        "  rid  kind       wall_ms  wait_ms  ticks  coverage  launch_reason"
    )
    for rid in sorted(paths)[:max_requests]:
        r = paths[rid]
        lines.append(
            f"  {rid:<4} {str(r['kind']):<10}"
            f" {1e3 * r['wall_s']:>7.2f}  {1e3 * r['wait_s']:>7.2f}"
            f"  {r['ticks']:>5}  {r['coverage']:>8.2%}  {r['reason']}"
        )
    if len(paths) > max_requests:
        lines.append(f"  ... {len(paths) - max_requests} more")
    lines.append("")
    lines.append("spans:")
    for name, s in summary["spans"].items():
        lines.append(
            f"  {name:<22} n={s['count']:<5} total={1e3 * s['total_s']:.2f}ms"
            f" p50={1e3 * s['p50_s']:.3f}ms p99={1e3 * s['p99_s']:.3f}ms"
        )
    lines.append("")
    lines.append(f"plan choices:   {summary['plan_choices']}")
    lines.append(f"launch reasons: {summary['launch_reasons']}")
    f = summary["store_fetch"]
    lines.append(
        f"store fetch:    {f['n_blocks']} blocks,"
        f" predicted {f['predicted_io_s']:.4f}s"
        f" observed {f['observed_io_s']:.4f}s"
    )
    lines.append(f"device transfers: {summary['device_transfers']}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="TraceRecorder JSONL export")
    ap.add_argument("--requests", type=int, default=20,
                    help="max per-request rows to print")
    args = ap.parse_args(argv)
    print(render(load_events(args.trace), max_requests=args.requests))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
